//! Sampled per-stage hot-path timing (DESIGN.md §7).
//!
//! Every instrumented site calls [`stage_timer`]; with sampling off
//! (rate 0, the default) that is one relaxed atomic load and a branch —
//! cheap enough to leave in the per-expert decode loop.  At rate `N`
//! each stage keeps its own decimation counter and times every Nth
//! occurrence, recording the elapsed seconds into a per-(stage, layer)
//! [`LatencyHistogram`] under a registry mutex that is only touched for
//! *sampled* occurrences.
//!
//! Determinism: the timer reads `Instant` and writes a side registry —
//! it never touches activations, weights, RNG state, or scheduling
//! decisions, so decoded token streams are bit-identical at any rate
//! (rust/tests/determinism.rs pins rate 1 vs off).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LatencyHistogram;

/// The sample rate `benches/obs_overhead.rs` gates at ≤ 2% tok/s cost —
/// what `--trace-sample` documentation calls the default-on rate (the
/// actual default is 0 = off).
pub const DEFAULT_SAMPLE: u32 = 64;

/// The stages of the serving hot path, in pipeline order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Token-row gather into the per-expert dispatch block.
    Gather,
    /// Butterfly orbit rotation (theta transpose-apply or phi apply).
    Rotate,
    /// Shared-substrate ternary GEMM (synthesis path, f32 or a8).
    TernaryGemm,
    /// Dense GEMM over a resident decoded expert (cache hit path).
    CachedGemm,
    /// Deterministic ascending-expert scatter/reduce into token rows.
    Reduce,
    /// Shared down projection.
    DownProject,
    /// One backend step that ingests prompt rows (chunked prefill).
    Prefill,
    /// One `ContinuousScheduler::step` (admission + decode + retire).
    SchedStep,
    /// One `Backend::tick_caches` residency sweep.
    CacheTick,
}

impl Stage {
    pub const ALL: [Stage; 9] = [
        Stage::Gather,
        Stage::Rotate,
        Stage::TernaryGemm,
        Stage::CachedGemm,
        Stage::Reduce,
        Stage::DownProject,
        Stage::Prefill,
        Stage::SchedStep,
        Stage::CacheTick,
    ];

    /// Stable snake_case name — the `stage` label value in `METRICS`.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Gather => "gather",
            Stage::Rotate => "rotate",
            Stage::TernaryGemm => "ternary_gemm",
            Stage::CachedGemm => "cached_gemm",
            Stage::Reduce => "reduce",
            Stage::DownProject => "down_project",
            Stage::Prefill => "prefill",
            Stage::SchedStep => "sched_step",
            Stage::CacheTick => "cache_tick",
        }
    }
}

static SAMPLE: AtomicU32 = AtomicU32::new(0);

/// Per-stage decimation counters (every instrumented occurrence bumps
/// its stage's counter; every Nth arms a timer).
static DECIM: [AtomicU64; 9] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

static REGISTRY: Mutex<BTreeMap<(Stage, u32), LatencyHistogram>> = Mutex::new(BTreeMap::new());

/// Set the global sample rate: 0 = off, N = time every Nth occurrence
/// per stage.  Process-global (`--trace-sample`).
pub fn set_sample(n: u32) {
    SAMPLE.store(n, Ordering::Relaxed);
}

pub fn sample() -> u32 {
    SAMPLE.load(Ordering::Relaxed)
}

/// Drop guard for one stage occurrence: unsampled guards carry nothing
/// and drop for free; sampled guards record elapsed seconds on drop.
pub struct StageTimer {
    armed: Option<(Stage, u32, Instant)>,
}

/// Start (or skip) a timer around one occurrence of `stage` in layer
/// `layer` (0 for the layerless stages).  The off fast path is a single
/// relaxed load + branch.
#[inline]
pub fn stage_timer(stage: Stage, layer: u32) -> StageTimer {
    let n = SAMPLE.load(Ordering::Relaxed);
    if n == 0 {
        return StageTimer { armed: None };
    }
    let tick = DECIM[stage as usize].fetch_add(1, Ordering::Relaxed);
    if tick % n as u64 != 0 {
        return StageTimer { armed: None };
    }
    StageTimer {
        armed: Some((stage, layer, Instant::now())),
    }
}

impl Drop for StageTimer {
    fn drop(&mut self) {
        if let Some((stage, layer, t0)) = self.armed.take() {
            let secs = t0.elapsed().as_secs_f64();
            let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
            reg.entry((stage, layer)).or_default().record(secs);
        }
    }
}

/// One (stage, layer) timing series, cloned out of the registry.
#[derive(Clone, Debug)]
pub struct StageStat {
    pub stage: Stage,
    pub layer: u32,
    pub hist: LatencyHistogram,
}

/// Snapshot every populated (stage, layer) histogram, ordered by stage
/// then layer.  Empty when sampling is off or nothing ran yet.
pub fn snapshot() -> Vec<StageStat> {
    let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    reg.iter()
        .map(|(&(stage, layer), hist)| StageStat {
            stage,
            layer,
            hist: hist.clone(),
        })
        .collect()
}

/// Serializes tests that mutate the process-global sample rate or
/// registry (used here and by `coordinator::metrics` tests) so the
/// harness can stay parallel.
#[doc(hidden)]
pub static TEST_MUTEX: Mutex<()> = Mutex::new(());

/// Clear recorded histograms and decimation counters (benches/tests).
pub fn reset() {
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).clear();
    for c in &DECIM {
        c.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_by_default_records_nothing_and_rate_one_records_everything() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let prev = sample();
        set_sample(0);
        reset();
        {
            let _t = stage_timer(Stage::Gather, 3);
        }
        assert!(
            snapshot().iter().all(|s| s.stage != Stage::Gather || s.layer != 3),
            "rate 0 must not record"
        );
        set_sample(1);
        for _ in 0..5 {
            let _t = stage_timer(Stage::Gather, 3);
        }
        let snap = snapshot();
        let got = snap
            .iter()
            .find(|s| s.stage == Stage::Gather && s.layer == 3)
            .expect("rate 1 records every occurrence");
        assert_eq!(got.hist.n, 5);
        set_sample(prev);
    }

    #[test]
    fn decimation_samples_every_nth() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let prev = sample();
        set_sample(10);
        reset();
        for _ in 0..100 {
            let _t = stage_timer(Stage::CacheTick, 7);
        }
        let snap = snapshot();
        let got = snap
            .iter()
            .find(|s| s.stage == Stage::CacheTick && s.layer == 7)
            .expect("sampled stage present");
        assert_eq!(got.hist.n, 10, "100 occurrences at rate 10 -> 10 samples");
        set_sample(prev);
    }

    #[test]
    fn stage_names_are_stable_and_unique() {
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Stage::ALL.len(), "label values must be unique");
    }
}
