//! Integration: generation sessions end-to-end — the coordinator's
//! continuous-batching loop over the real backends (PJRT LM when
//! `make artifacts` has run, native MoE always), plus cross-backend
//! invariants of the session API.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use butterfly_moe::coordinator::{
    collect_stream, greedy_next, Backend, Coordinator, FinishReason, GenerateRequest,
    InflightBatch, InflightSeq, NativeMoeBackend, PjrtLmBackend, SamplingParams, SchedulerConfig,
    StopCriteria,
};
use butterfly_moe::testutil;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

/// Native backend over the shared seeded fixture layer, with a worker
/// pool sized by the environment — CI runs this whole suite under
/// `BMOE_WORKERS=1` and `BMOE_WORKERS=4`, and every assertion below
/// must hold identically for both (decoded streams are worker-count
/// invariant).
fn native_backend(max_batch: usize) -> Arc<NativeMoeBackend> {
    let mut layer = testutil::butterfly_layer(64, 256, 8, 2, 7);
    layer.attach_worker_pool(testutil::env_pool());
    Arc::new(NativeMoeBackend::new(Arc::new(layer), 512, 32, max_batch))
}

#[test]
fn pjrt_lm_backend_steps_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let (backend, _join) = PjrtLmBackend::start(&dir, "tiny", None).unwrap();
    // single prompt, deterministic logits
    let a = greedy_next(&backend, &[vec![1, 2, 3]]).unwrap();
    let b = greedy_next(&backend, &[vec![1, 2, 3]]).unwrap();
    assert_eq!(a, b);
    assert!((0..512).contains(&a[0]));
    // bucket padding: 3 prompts -> bucket 4; batch-invariance of seq 0
    let outs = greedy_next(&backend, &[vec![1, 2, 3], vec![4, 5], vec![6]]).unwrap();
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[0], a[0]);
    // oversized step splits across buckets instead of dropping requests
    let many: Vec<Vec<i32>> = (0..backend.max_batch() + 3)
        .map(|i| vec![(i % 500) as i32, 3, 7])
        .collect();
    let mut batch = InflightBatch::new();
    for (i, p) in many.iter().enumerate() {
        batch.push(InflightSeq::new(i as u64, p.clone()));
    }
    let outs = backend.step(&mut batch).unwrap();
    assert_eq!(outs.len(), many.len());
}

#[test]
fn coordinator_streams_sessions_over_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let (backend, _join) = PjrtLmBackend::start(&dir, "tiny", None).unwrap();
    let coord = Coordinator::start(
        Arc::new(backend),
        SchedulerConfig::new(4, Duration::from_millis(4)),
    );
    let rxs: Vec<_> = (0..6)
        .map(|i| coord.submit(GenerateRequest::greedy(vec![i as i32 % 500, 3, 7], 4)))
        .collect();
    for rx in rxs {
        let c = collect_stream(&rx, Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens.len(), 4);
        assert_eq!(c.reason, FinishReason::MaxTokens);
        assert!(c.tokens.iter().all(|t| (0..512).contains(t)));
        assert!(c.ttft.is_some());
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.responses, 6);
    assert_eq!(snap.tokens, 24);
    assert_eq!(snap.errors, 0);
    coord.shutdown();
}

#[test]
fn native_sessions_under_concurrent_load() {
    let coord = Coordinator::start(
        native_backend(16),
        SchedulerConfig::new(16, Duration::from_millis(2)),
    );
    let rxs: Vec<_> = (0..100)
        .map(|i| coord.submit(GenerateRequest::greedy(vec![(i % 512) as i32; 8], 5)))
        .collect();
    for rx in rxs {
        let c = collect_stream(&rx, Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens.len(), 5);
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.responses, 100);
    assert_eq!(snap.tokens, 500);
    assert!(
        snap.mean_batch_size > 1.2,
        "continuous batching under load: occupancy {}",
        snap.mean_batch_size
    );
    assert!(snap.tokens_per_sec > 0.0);
    coord.shutdown();
}

#[test]
fn greedy_sessions_are_deterministic_across_coordinators() {
    let run = || {
        let coord = Coordinator::start(
            native_backend(8),
            SchedulerConfig::new(8, Duration::from_millis(1)),
        );
        let c = coord
            .generate(GenerateRequest::greedy(vec![5, 6, 7, 8], 12))
            .unwrap();
        coord.shutdown();
        c.tokens
    };
    assert_eq!(run(), run());
}

#[test]
fn seeded_temperature_sessions_replay_identically() {
    let coord = Coordinator::start(
        native_backend(8),
        SchedulerConfig::new(8, Duration::from_millis(1)),
    );
    let sample = |seed: u64| {
        let req = GenerateRequest::greedy(vec![1, 2, 3], 16)
            .with_sampling(SamplingParams::top_k(1.0, 40, seed));
        coord.generate(req).unwrap().tokens
    };
    assert_eq!(sample(99), sample(99), "same seed => same completion");
    assert_ne!(sample(1), sample(2), "different seeds should diverge");
    coord.shutdown();
}

#[test]
fn eos_cuts_generation_short() {
    let coord = Coordinator::start(
        native_backend(8),
        SchedulerConfig::new(8, Duration::from_millis(1)),
    );
    // discover what greedy decoding emits, then use its second token as
    // EOS: the session must stop right there
    let free = coord
        .generate(GenerateRequest::greedy(vec![9, 8, 7], 8))
        .unwrap();
    assert_eq!(free.tokens.len(), 8);
    let eos = free.tokens[1];
    let stopped = coord
        .generate(
            GenerateRequest::greedy(vec![9, 8, 7], 8)
                .with_stop(StopCriteria::max_tokens(8).with_eos(eos)),
        )
        .unwrap();
    assert_eq!(stopped.reason, FinishReason::Eos);
    assert_eq!(stopped.tokens, free.tokens[..2].to_vec());
    coord.shutdown();
}

#[test]
fn oversized_prompt_truncates_explicitly_and_decodes_the_tail() {
    // native fixture window is 32: a 100-token prompt drops its first
    // 68 positions, the completion says so, and the decoded stream is
    // exactly what the surviving 32-token suffix alone produces
    let coord = Coordinator::start(
        native_backend(8),
        SchedulerConfig::new(8, Duration::from_millis(1)),
    );
    let long: Vec<i32> = (0..100).map(|i| (i * 7) % 512).collect();
    let tail = long[68..].to_vec();
    let c_long = coord
        .generate(GenerateRequest::greedy(long, 6))
        .unwrap();
    assert_eq!(c_long.truncated, 68, "dropped prompt head must be surfaced");
    let c_tail = coord.generate(GenerateRequest::greedy(tail, 6)).unwrap();
    assert_eq!(c_tail.truncated, 0, "in-window prompt truncates nothing");
    assert_eq!(
        c_long.tokens, c_tail.tokens,
        "the model must see exactly the surviving suffix"
    );
    coord.shutdown();
}

#[test]
fn chunked_prefill_streams_identical_to_all_at_once() {
    // coordinator-level chunk invariance over the real native backend:
    // same sessions, chunks {1, 4, 0} — identical streams, and TTFT
    // fires once per session (on the first decoded token)
    let run = |chunk: usize| {
        let coord = Coordinator::start(
            native_backend(8),
            SchedulerConfig::new(8, Duration::from_millis(2)).with_prefill_chunk(chunk),
        );
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                coord.submit(GenerateRequest::greedy(
                    (0..9).map(|j| ((i * 131 + j * 17) % 512) as i32).collect(),
                    6,
                ))
            })
            .collect();
        let streams: Vec<Vec<i32>> = rxs
            .into_iter()
            .map(|rx| collect_stream(&rx, Duration::from_secs(30)).unwrap().tokens)
            .collect();
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.ttft_count, 4, "chunk {chunk}: one TTFT per session");
        assert_eq!(snap.tokens, 24, "chunk {chunk}: 4 sessions x 6 tokens");
        assert_eq!(
            snap.prefill_tokens, 4 * 9,
            "chunk {chunk}: every prompt token counted exactly once"
        );
        coord.shutdown();
        streams
    };
    let reference = run(0);
    assert!(reference.iter().all(|s| s.len() == 6));
    for chunk in [1usize, 4] {
        assert_eq!(run(chunk), reference, "chunk {chunk} changed a stream");
    }
}

#[test]
fn mixed_length_workload_short_finishes_first() {
    let coord = Coordinator::start(
        native_backend(8),
        SchedulerConfig::new(8, Duration::from_millis(1)),
    );
    let long = coord.submit(GenerateRequest::greedy(vec![1, 2, 3], 256));
    let short = coord.submit(GenerateRequest::greedy(vec![4, 5, 6], 4));
    let c_short = collect_stream(&short, Duration::from_secs(30)).unwrap();
    assert_eq!(c_short.tokens.len(), 4);
    let c_long = collect_stream(&long, Duration::from_secs(60)).unwrap();
    assert_eq!(c_long.tokens.len(), 256);
    // the short session must not pay for the long one's 256 steps
    assert!(
        c_short.total < c_long.total,
        "short ({:?}) should finish well before long ({:?})",
        c_short.total,
        c_long.total
    );
    coord.shutdown();
}

/// Router transparency: the fleet front door relays sessions verbatim,
/// so a seeded session through `bmoe route` decodes the exact token
/// stream a direct connection to a worker does.  Pinned over the wire
/// with in-process workers (same serving stack as child processes).
#[test]
fn router_in_front_streams_identical_tokens_to_direct() {
    use butterfly_moe::router::worker::{InProcessLauncher, WorkerLauncher};
    use butterfly_moe::router::{Router, RouterConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};

    fn session_tokens(addr: SocketAddr, gen: &str) -> (Vec<i32>, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{gen}").unwrap();
        let mut reader = BufReader::new(s);
        let mut toks = Vec::new();
        loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "stream truncated");
            if let Some(rest) = line.strip_prefix("TOK ") {
                toks.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
            } else {
                return (toks, line.trim().to_string());
            }
        }
    }

    let launcher = Arc::new(InProcessLauncher::new(Duration::ZERO, 8));
    // a standalone worker for the direct baseline...
    let (direct_addr, mut direct) = launcher.launch(100).unwrap();
    // ...and a 2-worker fleet behind a router
    let router = Router::start(
        RouterConfig {
            port: 0,
            fleet: 2,
            sessions_per_worker: 8,
            ..RouterConfig::default()
        },
        launcher,
    )
    .unwrap();
    let (listener, router_addr) = butterfly_moe::util::net::listen_reuse(0).unwrap();
    {
        let router = router.clone();
        std::thread::spawn(move || router.serve(listener));
    }
    // seeded temperature sampling: the decoded stream depends on the
    // seed, so equality means the router changed nothing
    for seed in [3u64, 99, 12345] {
        let gen = format!("GEN 12 0.8 8 {seed} -1 1 2 3");
        let (direct_toks, direct_end) = session_tokens(direct_addr, &gen);
        assert_eq!(direct_toks.len(), 12, "{direct_end}");
        for _ in 0..2 {
            // twice: round-robin lands the session on both fleet workers
            let (routed_toks, routed_end) = session_tokens(router_addr, &gen);
            assert_eq!(
                routed_toks, direct_toks,
                "same seed must decode identically through the router"
            );
            assert!(routed_end.starts_with("END max_tokens"), "{routed_end}");
        }
    }
    router.drain();
    direct.kill();
}

#[test]
fn shutdown_denies_queued_sessions_with_terminal_events() {
    // capacity 1 so most sessions are queued when shutdown hits; raise
    // the server-side session cap so the in-flight one can't finish first
    let coord = Coordinator::start(
        native_backend(1),
        SchedulerConfig::new(1, Duration::from_millis(1)).with_session_cap(1_000_000),
    );
    let rxs: Vec<_> = (0..12)
        .map(|_| coord.submit(GenerateRequest::greedy(vec![1, 2], 1_000_000)))
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    coord.shutdown();
    for rx in rxs {
        let c = collect_stream(&rx, Duration::from_secs(5))
            .expect("no waiter may be stranded on shutdown");
        assert_eq!(c.reason, FinishReason::Shutdown);
    }
}
