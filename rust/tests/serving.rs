//! Integration: coordinator + PJRT LM backend end-to-end — batched
//! requests through the real AOT graph, plus the native-engine backend
//! under concurrent load.
//!
//! Skips (passes vacuously) when `make artifacts` hasn't run.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use butterfly_moe::coordinator::{Backend, Coordinator, NativeMoeBackend, PjrtLmBackend};
use butterfly_moe::moe::ButterflyMoeLayer;
use butterfly_moe::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn pjrt_lm_backend_serves_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let (backend, _join) = PjrtLmBackend::start(&dir, "tiny", None).unwrap();
    // single
    let out = backend.forward(&[vec![1, 2, 3]]).unwrap();
    assert_eq!(out.len(), 1);
    assert!((0..512).contains(&out[0]));
    // deterministic
    let out2 = backend.forward(&[vec![1, 2, 3]]).unwrap();
    assert_eq!(out, out2);
    // bucket padding: 3 prompts -> bucket 4
    let outs = backend
        .forward(&[vec![1, 2, 3], vec![4, 5], vec![6]])
        .unwrap();
    assert_eq!(outs.len(), 3);
    // batch-invariance: the same prompt gives the same next token
    // regardless of batch-mates (static graphs, no cross-seq state)
    assert_eq!(outs[0], out[0]);
}

#[test]
fn coordinator_over_pjrt_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let (backend, _join) = PjrtLmBackend::start(&dir, "tiny", None).unwrap();
    let coord = Coordinator::start(Arc::new(backend), 4, Duration::from_millis(4), 2);

    let rxs: Vec<_> = (0..12)
        .map(|i| coord.submit(vec![i as i32 % 500, 3, 7]))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!((0..512).contains(&resp.next_token));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.responses, 12);
    assert_eq!(snap.errors, 0);
    assert!(snap.mean_batch_size >= 1.0);
    coord.shutdown();
}

#[test]
fn coordinator_over_native_backend_under_load() {
    // no artifacts needed: fully native path
    let mut rng = Rng::new(7);
    let layer = Arc::new(ButterflyMoeLayer::random(64, 256, 8, 2, None, &mut rng));
    let backend = Arc::new(NativeMoeBackend::new(layer, 512, 32, 16));
    let coord = Coordinator::start(backend, 16, Duration::from_millis(2), 4);

    let rxs: Vec<_> = (0..200)
        .map(|i| coord.submit(vec![(i % 512) as i32; 8]))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.responses, 200);
    assert!(snap.mean_batch_size > 1.2, "batching under load: {}", snap.mean_batch_size);
    assert!(snap.latency_p99 < 5.0);
    coord.shutdown();
}
