//! Integration: the fleet router end-to-end over REAL `bmoe serve`
//! child processes — the supervision paths that in-process unit tests
//! (rust/src/router/) cannot exercise: fork/exec launch with
//! `[listening]` discovery, SIGKILL mid-stream, process restart, and
//! the `bmoe route` CLI verb's drain-to-exit-0 contract.
//!
//! Hermetic-worker coverage (placement, shedding, fairness, backoff)
//! lives in the router's unit tests; stream equality through the router
//! lives in rust/tests/serving.rs.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::artifact::{synthesize, SynthSpec};
use butterfly_moe::router::{worker::ProcessLauncher, Router, RouterConfig};

fn bmoe_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_bmoe"))
}

/// Pack a model deep enough that a 28-token session takes visibly long
/// (several decode milliseconds per token), so kills and drains land
/// mid-stream instead of racing session completion.
fn pack_model(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("bmoe_router_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let spec = SynthSpec {
        d_model: 256,
        d_ff: 1024,
        n_experts: 4,
        top_k: 2,
        n_layers: 4,
        vocab: 128,
        seq_len: 32,
        depth: None,
        seed: 7,
    };
    synthesize(&spec).pack(&path).unwrap();
    path
}

fn worker_args(model: &Path) -> Vec<String> {
    [
        "--native",
        "--model",
        model.to_str().unwrap(),
        "--load",
        "mmap",
        "--max-batch",
        "4",
        "--workers",
        "1",
        "--no-warmup",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Read TOK lines until a terminal; returns (tokens, terminal line).
fn read_session(r: &mut BufReader<TcpStream>) -> (Vec<i32>, String) {
    let mut toks = Vec::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line).unwrap_or(0) == 0 {
            return (toks, "EOF".into());
        }
        if let Some(rest) = line.strip_prefix("TOK ") {
            toks.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
        } else {
            return (toks, line.trim().to_string());
        }
    }
}

fn run_session(addr: SocketAddr, gen: &str) -> (Vec<i32>, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "{gen}").unwrap();
    read_session(&mut BufReader::new(s))
}

fn stat_field(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|kv| kv.strip_prefix(key)?.strip_prefix('=')?.parse().ok())
        .unwrap_or_else(|| panic!("missing {key} in {line}"))
}

/// SIGKILLed worker process mid-stream, fleet of ONE (the hard case):
/// the relay declares the worker down, waits out the supervisor's
/// relaunch of a REAL replacement process, replays the seeded `GEN`
/// line on it, verifies + suppresses the already-delivered prefix, and
/// the client receives one complete stream bit-identical to a
/// fault-free run — no `ERR worker lost`, no hang, no duplicate token.
/// The ISSUE's failover acceptance, pinned over real child processes.
#[test]
fn killed_worker_process_fails_over_to_bit_identical_stream() {
    let model = pack_model("crash.bmoe");
    let cfg = RouterConfig {
        port: 0,
        fleet: 1,
        sessions_per_worker: 4,
        health_interval: Duration::from_millis(100),
        backoff_base: Duration::from_millis(100),
        failover_retries: 2,
        failover_wait: Duration::from_secs(60),
        ..RouterConfig::default()
    };
    let launcher = Arc::new(ProcessLauncher::new(bmoe_bin(), worker_args(&model)));
    let (listener, addr) = butterfly_moe::util::net::listen_reuse(0).unwrap();
    let router = Router::start(cfg, launcher).unwrap();
    {
        let router = router.clone();
        std::thread::spawn(move || router.serve(listener));
    }
    // fault-free reference of the exact request (decoded streams are
    // deterministic, so a replay on a fresh process reproduces it)
    let (baseline, base_end) = run_session(addr, "GEN 28 0 0 0 -1 1 2");
    assert_eq!(baseline.len(), 28, "{base_end}");
    assert!(base_end.starts_with("END max_tokens 28 "), "{base_end}");
    // same session again; 4-layer model => multi-ms per token, so the
    // SIGKILL lands mid-stream
    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "GEN 28 0 0 0 -1 1 2").unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut first = String::new();
    r.read_line(&mut first).unwrap();
    assert!(first.starts_with("TOK "), "{first}");
    router.kill_worker(0);
    let (rest, end) = read_session(&mut r);
    let mut full: Vec<i32> = vec![first
        .strip_prefix("TOK ")
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap()];
    full.extend(rest);
    assert!(
        end.starts_with("END max_tokens 28 "),
        "failover must finish the stream, not ERR: {end}"
    );
    assert_eq!(full, baseline, "failover stream must be bit-identical");
    // the failover is visible in telemetry, the loss is not
    let mut sc = TcpStream::connect(addr).unwrap();
    writeln!(sc, "STATS").unwrap();
    let mut line = String::new();
    BufReader::new(sc).read_line(&mut line).unwrap();
    assert!(stat_field(&line, "failovers") >= 1, "{line}");
    assert_eq!(stat_field(&line, "worker_lost"), 0, "{line}");
    assert_eq!(stat_field(&line, "diverged"), 0, "{line}");
    assert!(router.fleet.views()[0].restarts >= 1, "restart must be counted");
    // the relaunched process keeps serving
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (toks, end) = run_session(addr, "GEN 3 0 0 0 -1 5 6");
        if toks.len() == 3 && end.starts_with("END max_tokens") {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "worker never recovered; last outcome: {end}"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    router.drain();
}

/// The `bmoe route` CLI verb end-to-end: boots 2 mmap workers, spreads
/// a sequential burst across both, completes in-flight sessions through
/// a DRAIN issued mid-stream (loss-free), and exits 0.
#[test]
fn route_cli_spreads_load_and_drains_to_exit_zero() {
    let model = pack_model("cli.bmoe");
    let mut child = std::process::Command::new(bmoe_bin())
        .args([
            "route",
            "--fleet",
            "2",
            "--model",
            model.to_str().unwrap(),
            "--load",
            "mmap",
            "--port",
            "0",
            "--sessions-per-worker",
            "4",
            "--max-batch",
            "4",
            "--workers",
            "1",
            "--health-interval-ms",
            "100",
            "--no-warmup",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit())
        .spawn()
        .unwrap();
    // the router's own [listening] line announces the front door; a
    // reader thread guards against a wedged boot
    let stdout = child.stdout.take().unwrap();
    let (tx, rx) = std::sync::mpsc::channel::<SocketAddr>();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if let Some(rest) = line.trim().strip_prefix("[listening] ") {
                if let Ok(addr) = rest.trim().parse() {
                    let _ = tx.send(addr);
                }
            }
        }
    });
    let addr = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("route never announced [listening]");

    // sequential short burst: round-robin tie-breaking must put tokens
    // on both workers
    for i in 0..6 {
        let (toks, end) = run_session(addr, &format!("GEN 3 0 0 0 -1 1 {i}"));
        assert_eq!(toks.len(), 3, "burst session {i}: {end}");
        assert!(end.starts_with("END max_tokens"), "{end}");
    }
    // counters are bumped just after the terminal is forwarded — poll
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "STATS").unwrap();
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).unwrap();
        if stat_field(&line, "routed") == 6 {
            assert!(stat_field(&line, "w0_tokens") > 0, "{line}");
            assert!(stat_field(&line, "w1_tokens") > 0, "{line}");
            assert_eq!(stat_field(&line, "shed"), 0, "{line}");
            break;
        }
        assert!(Instant::now() < deadline, "routed never reached 6: {line}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // two long sessions in flight, then DRAIN mid-stream: both must
    // still run to their terminal (accepted means completed)
    let mut inflight = Vec::new();
    for i in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 28 0 0 0 -1 9 {i}").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut first = String::new();
        r.read_line(&mut first).unwrap();
        assert!(first.starts_with("TOK "), "{first}");
        inflight.push((s, r));
    }
    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "DRAIN").unwrap();
    let mut ack = String::new();
    BufReader::new(s).read_line(&mut ack).unwrap();
    assert_eq!(ack.trim(), "OK draining");
    for (_s, mut r) in inflight {
        let (toks, end) = read_session(&mut r);
        assert_eq!(toks.len(), 27, "in-flight session must finish through drain: {end}");
        assert!(end.starts_with("END max_tokens"), "{end}");
    }
    // loss-free drain then a clean exit
    let deadline = Instant::now() + Duration::from_secs(60);
    let status = loop {
        if let Some(st) = child.try_wait().unwrap() {
            break st;
        }
        assert!(Instant::now() < deadline, "route process never exited after DRAIN");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "bmoe route must exit 0 after drain, got {status:?}");
}
