//! Property tests for the blocked SIMD kernel suite (§Perf iteration 6):
//! the stage-outer blocked butterfly and the register-blocked GEMMs must
//! be **bit-identical** to their per-row / per-dot references across
//! random shapes — odd row counts (tail tiles), every butterfly depth,
//! token counts straddling the `NR`/`MC` tile edges — and the parity
//! must survive the expert cache at partial budgets and worker-range
//! sharding of the down projection, since tile boundaries move with the
//! range splits.

use std::sync::Arc;

use butterfly_moe::butterfly::Butterfly;
use butterfly_moe::expertcache::{decoded_expert_bytes, DecodedExpert, ExpertCacheConfig};
use butterfly_moe::kernels::{self, TernaryScratch, NR, RB};
use butterfly_moe::moe::MoeLayer;
use butterfly_moe::parallel::WorkerPool;
use butterfly_moe::testutil;
use butterfly_moe::util::Rng;

/// Token counts straddling the micro-kernel tile edges, as the issue
/// prescribes: {1, Nr-1, Nr, 3·Nr+1}.
fn token_counts() -> [usize; 4] {
    [1, NR - 1, NR, 3 * NR + 1]
}

#[test]
fn blocked_butterfly_bit_identical_to_per_row_across_shapes() {
    for d in [2usize, 16, 128] {
        for depth in 1..=Butterfly::max_depth(d) {
            let mut rng = Rng::new((d * 31 + depth) as u64);
            let b = Butterfly::random(d, depth, 0.7, &mut rng);
            // odd row counts hit the tail block of the RB blocking
            for rows in [1usize, 3, RB - 1, RB, 2 * RB + 5] {
                let src = testutil::normal_vec(rows * d, (rows * d) as u64);
                let mut per_row = src.clone();
                let mut blocked = src.clone();
                b.apply_batch_per_row(&mut per_row);
                b.apply_batch(&mut blocked);
                assert_eq!(blocked, per_row, "forward d={d} depth={depth} rows={rows}");
                let mut per_row_t = src.clone();
                let mut blocked_t = src;
                b.apply_transpose_batch_per_row(&mut per_row_t);
                b.apply_transpose_batch(&mut blocked_t);
                assert_eq!(
                    blocked_t, per_row_t,
                    "transpose d={d} depth={depth} rows={rows}"
                );
            }
        }
    }
}

#[test]
fn blocked_ternary_gemm_bit_identical_to_dot_loop_reference() {
    let mut scratch = TernaryScratch::default();
    // row counts hit NR tails; cols hit the 64-column word tail
    for (rows, cols, seed) in [
        (1usize, 64usize, 1u64),
        (NR - 1, 96, 2),
        (NR, 128, 3),
        (3 * NR + 1, 200, 4),
        (33, 100, 5),
    ] {
        let q = testutil::random_quant(rows, cols, seed);
        let bp = butterfly_moe::ternary::BitplaneTernary::from_quant(&q);
        for t in token_counts() {
            let x = testutil::normal_vec(t * cols, seed * 100 + t as u64);
            let mut blocked = vec![0.0f32; t * rows];
            let mut reference = vec![0.0f32; t * rows];
            bp.gemm_with(&x, t, &mut blocked, &mut scratch);
            bp.gemm_ref(&x, t, &mut reference);
            assert_eq!(blocked, reference, "f32 ({rows},{cols}) t={t}");
            let mut blocked_a8 = vec![0.0f32; t * rows];
            let mut reference_a8 = vec![0.0f32; t * rows];
            bp.gemm_a8_with(&x, t, &mut blocked_a8, &mut scratch);
            bp.gemm_a8_ref(&x, t, &mut reference_a8);
            assert_eq!(blocked_a8, reference_a8, "a8 ({rows},{cols}) t={t}");
        }
    }
}

#[test]
fn decoded_expert_gemm_bit_identical_to_synthesis_gemm() {
    // the cached/uncached parity contract: both sides route through the
    // same micro-kernel, so swapping paths never changes a bit
    let mut scratch = TernaryScratch::default();
    for (rows, cols, seed) in [(16usize, 64usize, 7u64), (13, 200, 8), (NR + 1, 96, 9)] {
        let sub = testutil::random_substrate(rows, cols, seed);
        let dec = DecodedExpert::materialize(&sub);
        for t in token_counts() {
            let x = testutil::normal_vec(t * cols, seed * 50 + t as u64);
            let mut cached = vec![0.0f32; t * rows];
            let mut synth = vec![0.0f32; t * rows];
            dec.gemm(&x, t, &mut cached);
            sub.gemm_with(&x, t, &mut synth, &mut scratch);
            assert_eq!(cached, synth, "({rows},{cols}) t={t}");
        }
    }
}

#[test]
fn dense_gemm_wrapper_matches_dot_f32_loop() {
    // the down projection's kernel: every output carries dot_f32's bits
    for (rows, cols) in [(5usize, 48usize), (12, 64), (NR, 32)] {
        let w = testutil::normal_vec(rows * cols, 21);
        for t in token_counts() {
            let x = testutil::normal_vec(t * cols, 22 + t as u64);
            let mut y = vec![0.0f32; t * rows];
            kernels::gemm_f32(&w, rows, cols, &x, t, 1.0, &mut y);
            for i in 0..t {
                for r in 0..rows {
                    let want = butterfly_moe::util::dot_f32(
                        &w[r * cols..(r + 1) * cols],
                        &x[i * cols..(i + 1) * cols],
                    );
                    assert_eq!(y[i * rows + r], want, "({rows},{cols}) t={t} i={i} r={r}");
                }
            }
        }
    }
}

#[test]
fn partial_cache_budget_forward_bit_identical_with_blocked_kernels() {
    // partial residency mixes decoded-GEMM and synthesis-GEMM dispatch
    // blocks inside one forward; outputs must match the cache-less layer
    // bit-for-bit through admission/eviction churn
    const D: usize = 32;
    const DFF: usize = 128;
    const E: usize = 8;
    let plain = testutil::butterfly_layer(D, DFF, E, 2, 71);
    let mut cached = testutil::butterfly_layer(D, DFF, E, 2, 71);
    let cache = cached.attach_expert_cache(ExpertCacheConfig {
        ewma_alpha: 0.5,
        min_resident_ticks: 1,
        max_admissions_per_tick: 2,
        ..ExpertCacheConfig::with_budget_bytes(3 * decoded_expert_bytes(DFF, D))
    });
    for round in 0..12u64 {
        for t in token_counts() {
            let x = testutil::normal_vec(t * D, 1000 + round * 17 + t as u64);
            let mut ha = vec![0.0f32; t * DFF];
            let mut hb = vec![0.0f32; t * DFF];
            let la = plain.experts_forward(&x, t, &mut ha);
            let lb = cached.experts_forward(&x, t, &mut hb);
            assert_eq!(ha, hb, "round={round} t={t}: partial-budget parity");
            assert_eq!(la, lb, "round={round} t={t}: loads");
            cache.tick();
        }
    }
    let s = cache.snapshot();
    assert!(s.hits > 0, "partial budget must serve some hits");
    assert!(s.misses > 0, "partial budget must also miss");
    assert!(s.resident_bytes <= s.budget_bytes);
}

#[test]
fn down_projection_bits_survive_worker_range_splits() {
    // chunk_ranges hands non-tile-aligned row windows to tasks; the
    // tile-position-independent kernel must keep full forwards
    // bit-identical across worker counts anyway
    const D: usize = 32; // threads*4 ranges slice 32 rows unevenly at 3 workers
    const DFF: usize = 64;
    let x = testutil::normal_vec(5 * D, 81);
    let sequential = testutil::butterfly_layer(D, DFF, 8, 2, 80);
    let mut want = vec![0.0f32; 5 * D];
    sequential.forward(&x, 5, &mut want);
    for workers in [1usize, 3, 5, 8] {
        let mut l = testutil::butterfly_layer(D, DFF, 8, 2, 80);
        l.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
        let mut y = vec![0.0f32; 5 * D];
        l.forward(&x, 5, &mut y);
        assert_eq!(y, want, "workers={workers}");
    }
}
