//! Property tests for the blocked SIMD kernel suite (§Perf iteration 6):
//! the stage-outer blocked butterfly and the register-blocked GEMMs must
//! be **bit-identical** to their per-row / per-dot references across
//! random shapes — odd row counts (tail tiles), every butterfly depth,
//! token counts straddling the `NR`/`MC` tile edges — and the parity
//! must survive the expert cache at partial budgets and worker-range
//! sharding of the down projection, since tile boundaries move with the
//! range splits.
//!
//! §Perf iteration 8 extends the contract **cross-ISA**: every property
//! also holds per force-selected kernel path (`scalar`/`avx2`/`neon`) —
//! bit-identical to the blocked-scalar reference for the f32 kernels,
//! exactly equal for the i8 kernels.  The `*_across_isas` tests drive
//! the explicit `*_on(isa, …)` entry points so they neither depend on
//! nor perturb the process-global dispatch; unavailable ISAs are
//! reported skips (see [`for_each_isa`]), never silently vacuous
//! passes.

use std::sync::Arc;

use butterfly_moe::butterfly::Butterfly;
use butterfly_moe::expertcache::{decoded_expert_bytes, DecodedExpert, ExpertCacheConfig};
use butterfly_moe::kernels::{self, Isa, TernaryScratch, NR, RB};
use butterfly_moe::moe::MoeLayer;
use butterfly_moe::parallel::WorkerPool;
use butterfly_moe::testutil;
use butterfly_moe::util::Rng;

/// Token counts straddling the micro-kernel tile edges, as the issue
/// prescribes: {1, Nr-1, Nr, 3·Nr+1}.
fn token_counts() -> [usize; 4] {
    [1, NR - 1, NR, 3 * NR + 1]
}

#[test]
fn blocked_butterfly_bit_identical_to_per_row_across_shapes() {
    for d in [2usize, 16, 128] {
        for depth in 1..=Butterfly::max_depth(d) {
            let mut rng = Rng::new((d * 31 + depth) as u64);
            let b = Butterfly::random(d, depth, 0.7, &mut rng);
            // odd row counts hit the tail block of the RB blocking
            for rows in [1usize, 3, RB - 1, RB, 2 * RB + 5] {
                let src = testutil::normal_vec(rows * d, (rows * d) as u64);
                let mut per_row = src.clone();
                let mut blocked = src.clone();
                b.apply_batch_per_row(&mut per_row);
                b.apply_batch(&mut blocked);
                assert_eq!(blocked, per_row, "forward d={d} depth={depth} rows={rows}");
                let mut per_row_t = src.clone();
                let mut blocked_t = src;
                b.apply_transpose_batch_per_row(&mut per_row_t);
                b.apply_transpose_batch(&mut blocked_t);
                assert_eq!(
                    blocked_t, per_row_t,
                    "transpose d={d} depth={depth} rows={rows}"
                );
            }
        }
    }
}

#[test]
fn blocked_ternary_gemm_bit_identical_to_dot_loop_reference() {
    let mut scratch = TernaryScratch::default();
    // row counts hit NR tails; cols hit the 64-column word tail
    for (rows, cols, seed) in [
        (1usize, 64usize, 1u64),
        (NR - 1, 96, 2),
        (NR, 128, 3),
        (3 * NR + 1, 200, 4),
        (33, 100, 5),
    ] {
        let q = testutil::random_quant(rows, cols, seed);
        let bp = butterfly_moe::ternary::BitplaneTernary::from_quant(&q);
        for t in token_counts() {
            let x = testutil::normal_vec(t * cols, seed * 100 + t as u64);
            let mut blocked = vec![0.0f32; t * rows];
            let mut reference = vec![0.0f32; t * rows];
            bp.gemm_with(&x, t, &mut blocked, &mut scratch);
            bp.gemm_ref(&x, t, &mut reference);
            assert_eq!(blocked, reference, "f32 ({rows},{cols}) t={t}");
            let mut blocked_a8 = vec![0.0f32; t * rows];
            let mut reference_a8 = vec![0.0f32; t * rows];
            bp.gemm_a8_with(&x, t, &mut blocked_a8, &mut scratch);
            bp.gemm_a8_ref(&x, t, &mut reference_a8);
            assert_eq!(blocked_a8, reference_a8, "a8 ({rows},{cols}) t={t}");
        }
    }
}

#[test]
fn decoded_expert_gemm_bit_identical_to_synthesis_gemm() {
    // the cached/uncached parity contract: both sides route through the
    // same micro-kernel, so swapping paths never changes a bit
    let mut scratch = TernaryScratch::default();
    for (rows, cols, seed) in [(16usize, 64usize, 7u64), (13, 200, 8), (NR + 1, 96, 9)] {
        let sub = testutil::random_substrate(rows, cols, seed);
        let dec = DecodedExpert::materialize(&sub);
        for t in token_counts() {
            let x = testutil::normal_vec(t * cols, seed * 50 + t as u64);
            let mut cached = vec![0.0f32; t * rows];
            let mut synth = vec![0.0f32; t * rows];
            dec.gemm(&x, t, &mut cached);
            sub.gemm_with(&x, t, &mut synth, &mut scratch);
            assert_eq!(cached, synth, "({rows},{cols}) t={t}");
        }
    }
}

#[test]
fn dense_gemm_wrapper_matches_dot_f32_loop() {
    // the down projection's kernel: every output carries dot_f32's bits
    for (rows, cols) in [(5usize, 48usize), (12, 64), (NR, 32)] {
        let w = testutil::normal_vec(rows * cols, 21);
        for t in token_counts() {
            let x = testutil::normal_vec(t * cols, 22 + t as u64);
            let mut y = vec![0.0f32; t * rows];
            kernels::gemm_f32(&w, rows, cols, &x, t, 1.0, &mut y);
            for i in 0..t {
                for r in 0..rows {
                    let want = butterfly_moe::util::dot_f32(
                        &w[r * cols..(r + 1) * cols],
                        &x[i * cols..(i + 1) * cols],
                    );
                    assert_eq!(y[i * rows + r], want, "({rows},{cols}) t={t} i={i} r={r}");
                }
            }
        }
    }
}

#[test]
fn partial_cache_budget_forward_bit_identical_with_blocked_kernels() {
    // partial residency mixes decoded-GEMM and synthesis-GEMM dispatch
    // blocks inside one forward; outputs must match the cache-less layer
    // bit-for-bit through admission/eviction churn
    const D: usize = 32;
    const DFF: usize = 128;
    const E: usize = 8;
    let plain = testutil::butterfly_layer(D, DFF, E, 2, 71);
    let mut cached = testutil::butterfly_layer(D, DFF, E, 2, 71);
    let cache = cached.attach_expert_cache(ExpertCacheConfig {
        ewma_alpha: 0.5,
        min_resident_ticks: 1,
        max_admissions_per_tick: 2,
        ..ExpertCacheConfig::with_budget_bytes(3 * decoded_expert_bytes(DFF, D))
    });
    for round in 0..12u64 {
        for t in token_counts() {
            let x = testutil::normal_vec(t * D, 1000 + round * 17 + t as u64);
            let mut ha = vec![0.0f32; t * DFF];
            let mut hb = vec![0.0f32; t * DFF];
            let la = plain.experts_forward(&x, t, &mut ha);
            let lb = cached.experts_forward(&x, t, &mut hb);
            assert_eq!(ha, hb, "round={round} t={t}: partial-budget parity");
            assert_eq!(la, lb, "round={round} t={t}: loads");
            cache.tick();
        }
    }
    let s = cache.snapshot();
    assert!(s.hits > 0, "partial budget must serve some hits");
    assert!(s.misses > 0, "partial budget must also miss");
    assert!(s.resident_bytes <= s.budget_bytes);
}

/// Run `check` once per *available* ISA.  Unavailable paths print a
/// loud skip notice; the scalar reference and the detected path must
/// always run, so a test can never pass vacuously (e.g. a typo'd cfg
/// gate compiling the SIMD modules out would fail here, not silently
/// shrink coverage).
fn for_each_isa(test: &str, mut check: impl FnMut(Isa)) {
    let mut ran = Vec::new();
    for isa in Isa::ALL {
        if isa.available() {
            check(isa);
            ran.push(isa);
        } else {
            eprintln!("SKIP [{test}]: kernel ISA '{isa}' unavailable on this machine");
        }
    }
    assert!(ran.contains(&Isa::Scalar), "{test}: the scalar reference must run");
    assert!(
        ran.contains(&Isa::detect()),
        "{test}: the detected ISA {} must run",
        Isa::detect()
    );
}

#[test]
fn butterfly_blocked_bit_identical_across_isas() {
    // odd row counts (tail blocks) x every depth x every ISA, forward
    // and transpose, against the per-row reference apply
    for_each_isa("butterfly", |isa| {
        let mut scratch = Vec::new();
        for d in [2usize, 16, 128] {
            for depth in 1..=Butterfly::max_depth(d) {
                let mut rng = Rng::new((d * 131 + depth) as u64);
                let b = Butterfly::random(d, depth, 0.7, &mut rng);
                for rows in [1usize, 3, RB - 1, RB, 2 * RB + 5] {
                    let src = testutil::normal_vec(rows * d, (rows * d) as u64 + 9);
                    for transpose in [false, true] {
                        let mut want = src.clone();
                        if transpose {
                            b.apply_transpose_batch_per_row(&mut want);
                        } else {
                            b.apply_batch_per_row(&mut want);
                        }
                        let mut got = src.clone();
                        kernels::butterfly_apply_blocked_on(
                            isa,
                            b.cs_table(),
                            d,
                            depth,
                            transpose,
                            &mut got,
                            &mut scratch,
                        );
                        assert_eq!(
                            got, want,
                            "isa={isa} d={d} depth={depth} rows={rows} transpose={transpose}"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn gemm_f32_bit_identical_across_isas() {
    // token counts straddle the NR/MC tile edges; rows hit NR tails;
    // every output must carry dot_f32's exact bits on every path
    for_each_isa("gemm_f32", |isa| {
        for (rows, cols) in [(1usize, 16usize), (NR - 1, 48), (NR, 64), (13, 100), (33, 200)] {
            let w = testutil::normal_vec(rows * cols, (rows * cols) as u64);
            for t in token_counts() {
                let x = testutil::normal_vec(t * cols, (t * cols) as u64 + 3);
                let mut y = vec![0.0f32; t * rows];
                kernels::gemm_f32_strided_on(isa, &w, rows, cols, &x, t, 0.73, &mut y, 0, rows);
                for i in 0..t {
                    for r in 0..rows {
                        let want = butterfly_moe::util::dot_f32(
                            &w[r * cols..(r + 1) * cols],
                            &x[i * cols..(i + 1) * cols],
                        ) * 0.73;
                        assert_eq!(y[i * rows + r], want, "isa={isa} ({rows},{cols}) t={t} r={r}");
                    }
                }
            }
        }
    });
}

#[test]
fn gemm_f32_split_position_invariant_across_isas() {
    // the worker-range property per ISA: non-aligned row-range splits
    // (as chunk_ranges hands to tasks) produce the bits of one call
    let (rows, cols, t) = (11usize, 48usize, 4usize);
    let w = testutil::normal_vec(rows * cols, 41);
    let x = testutil::normal_vec(t * cols, 42);
    let mut whole = vec![0.0f32; t * rows];
    kernels::gemm_f32_strided(&w, rows, cols, &x, t, 1.0, &mut whole, 0, rows);
    for_each_isa("gemm_f32 splits", |isa| {
        for split in 1..rows {
            let mut parts = vec![0.0f32; t * rows];
            kernels::gemm_f32_strided_on(
                isa,
                &w[..split * cols],
                split,
                cols,
                &x,
                t,
                1.0,
                &mut parts,
                0,
                rows,
            );
            kernels::gemm_f32_strided_on(
                isa,
                &w[split * cols..],
                rows - split,
                cols,
                &x,
                t,
                1.0,
                &mut parts,
                split,
                rows,
            );
            assert_eq!(parts, whole, "isa={isa} split at {split}");
        }
    });
}

#[test]
fn gemm_i8_exactly_equal_across_isas() {
    // integer accumulation is exact, so every ISA returns the same i32
    // (and hence the same f32 after the per-token scale) — exactly
    let mut rng = Rng::new(77);
    for (rows, cols) in [(1usize, 15usize), (NR, 64), (NR + 1, 96), (13, 200)] {
        let w: Vec<i8> = (0..rows * cols)
            .map(|_| (rng.normal_f32(1.0) as i32).clamp(-1, 1) as i8)
            .collect();
        for t in token_counts() {
            let xq: Vec<i8> = (0..t * cols)
                .map(|_| (rng.normal_f32(40.0) as i32).clamp(-127, 127) as i8)
                .collect();
            let scales: Vec<f32> = (0..t).map(|i| 0.01 + i as f32 * 0.003).collect();
            let mut want = vec![0.0f32; t * rows];
            kernels::gemm_i8_strided(&w, rows, cols, &xq, t, &scales, &mut want, 0, rows);
            for_each_isa("gemm_i8", |isa| {
                for i in 0..t {
                    for r in 0..rows {
                        let d = kernels::dot_i8_on(
                            isa,
                            &w[r * cols..(r + 1) * cols],
                            &xq[i * cols..(i + 1) * cols],
                        );
                        let ds = kernels::dot_i8_on(
                            Isa::Scalar,
                            &w[r * cols..(r + 1) * cols],
                            &xq[i * cols..(i + 1) * cols],
                        );
                        assert_eq!(d, ds, "isa={isa} dot ({rows},{cols}) t={t} i={i} r={r}");
                    }
                }
                let mut y = vec![0.0f32; t * rows];
                kernels::gemm_i8_strided_on(isa, &w, rows, cols, &xq, t, &scales, &mut y, 0, rows);
                assert_eq!(y, want, "isa={isa} gemm ({rows},{cols}) t={t}");
            });
        }
    }
}

#[test]
fn dot_i8_exact_at_maximum_depth() {
    // the i32-accumulation bound (kernels::MAX_I8_DOT_LEN): a length
    // 2^16 dot of all-(+/-)127 values is the worst case the kernel
    // admits — 127^2 * 65536 = 1_057_030_144 < i32::MAX — and every ISA
    // must return it exactly
    let n = kernels::MAX_I8_DOT_LEN;
    let a = vec![127i8; n];
    let b: Vec<i8> = (0..n).map(|j| if j % 2 == 0 { 127 } else { -127 }).collect();
    let same: i64 = (n as i64) * 127 * 127;
    assert_eq!(same, 1_057_030_144, "worst case stays below i32::MAX");
    for_each_isa("dot_i8 max depth", |isa| {
        assert_eq!(kernels::dot_i8_on(isa, &a, &a), same as i32, "isa={isa} aligned max");
        // alternating signs cancel exactly
        assert_eq!(kernels::dot_i8_on(isa, &a, &b), 0, "isa={isa} alternating");
        // one past a 16-lane boundary exercises the scalar tail at depth
        let m = n - LANES_I8_TAIL;
        assert_eq!(
            kernels::dot_i8_on(isa, &a[..m], &a[..m]),
            (m as i64 * 127 * 127) as i32,
            "isa={isa} tail"
        );
    });
}

/// Shave an odd remainder off `MAX_I8_DOT_LEN` so the max-depth test
/// also exercises the non-multiple-of-16 tail path.
const LANES_I8_TAIL: usize = 7;

#[test]
fn down_projection_bits_survive_worker_range_splits() {
    // chunk_ranges hands non-tile-aligned row windows to tasks; the
    // tile-position-independent kernel must keep full forwards
    // bit-identical across worker counts anyway
    const D: usize = 32; // threads*4 ranges slice 32 rows unevenly at 3 workers
    const DFF: usize = 64;
    let x = testutil::normal_vec(5 * D, 81);
    let sequential = testutil::butterfly_layer(D, DFF, 8, 2, 80);
    let mut want = vec![0.0f32; 5 * D];
    sequential.forward(&x, 5, &mut want);
    for workers in [1usize, 3, 5, 8] {
        let mut l = testutil::butterfly_layer(D, DFF, 8, 2, 80);
        l.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
        let mut y = vec![0.0f32; 5 * D];
        l.forward(&x, 5, &mut y);
        assert_eq!(y, want, "workers={workers}");
    }
}
