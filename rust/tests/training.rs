//! Integration: the Rust training driver over the AOT train-step
//! artifact — loss must descend, checkpoints must round-trip, and the
//! static-rotation baseline must leave its angles untouched.
//!
//! Skips (passes vacuously) when `make artifacts` hasn't run.

use std::path::PathBuf;

use butterfly_moe::config::RuntimeConfig;
use butterfly_moe::runtime::Engine;
use butterfly_moe::train::{load_checkpoint_values, Trainer};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

fn runtime(steps: usize, out: &str) -> RuntimeConfig {
    RuntimeConfig {
        steps,
        lr: 3e-3,
        warmup_steps: 5,
        checkpoint_every: 0,
        out_dir: std::env::temp_dir()
            .join(out)
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    }
}

#[test]
fn tiny_training_descends_and_checkpoints() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let mut trainer = Trainer::new(&engine, runtime(30, "bmoe_it_train"));
    trainer.quiet = true;
    let report = trainer.run("tiny", None).unwrap();

    assert_eq!(report.logs.len(), 30);
    assert!(report.logs.iter().all(|l| l.loss.is_finite()));
    let first5: f32 = report.logs[..5].iter().map(|l| l.loss).sum::<f32>() / 5.0;
    let last5: f32 = report.logs[25..].iter().map(|l| l.loss).sum::<f32>() / 5.0;
    assert!(
        last5 < first5 - 0.05,
        "loss should descend: {first5} -> {last5}"
    );

    // checkpoint roundtrip preserves every tensor
    let ckpt = std::env::temp_dir().join("bmoe_it_train/tiny_test.bmoe");
    report.save_checkpoint(&ckpt).unwrap();
    let back = load_checkpoint_values(&ckpt, &report.param_names).unwrap();
    assert_eq!(back.len(), report.final_params.len());
    for (a, b) in back.iter().zip(&report.final_params) {
        let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data);
    }

    // eval artifact runs on the trained params
    let ce = trainer.eval("tiny", &report.final_params, 2).unwrap();
    assert!(ce.is_finite() && ce > 0.0);

    // loss curve CSV
    let csv = std::env::temp_dir().join("bmoe_it_train/loss.csv");
    report.write_csv(&csv).unwrap();
    let text = std::fs::read_to_string(&csv).unwrap();
    assert!(text.lines().count() == 31); // header + 30 steps
}

#[test]
fn static_rotations_do_not_move_under_training() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let mut trainer = Trainer::new(&engine, runtime(6, "bmoe_it_static"));
    trainer.quiet = true;
    let report = trainer.run("tiny_static", None).unwrap();
    let init = engine.load_params("tiny_static").unwrap();
    for ((name, after), before) in report
        .param_names
        .iter()
        .zip(&report.final_params)
        .zip(&init)
    {
        let is_rotation = name.contains("theta") || name.contains("phi");
        let (a, b) = (after.as_f32().unwrap(), before.as_f32().unwrap());
        let delta = a.max_abs_diff(b);
        if is_rotation {
            assert_eq!(delta, 0.0, "{name} moved by {delta}");
        }
    }
    // ...but the substrate did move
    let moved = report
        .param_names
        .iter()
        .zip(&report.final_params)
        .zip(&init)
        .filter(|((n, _), _)| n.contains("w_base"))
        .all(|((_, a), b)| a.as_f32().unwrap().max_abs_diff(b.as_f32().unwrap()) > 0.0);
    assert!(moved);
}

#[test]
fn standard_and_dense_baselines_train() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    for cfg in ["tiny_standard", "tiny_dense"] {
        let mut trainer = Trainer::new(&engine, runtime(8, "bmoe_it_baselines"));
        trainer.quiet = true;
        let report = trainer.run(cfg, None).unwrap();
        assert!(report.final_loss().is_finite(), "{cfg}");
    }
}
