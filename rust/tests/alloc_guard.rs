//! Zero-steady-state-allocation guard for the blocked kernel suite.
//!
//! The §Perf-iteration-6 scratch hoist moved every per-call allocation
//! of the hot-path kernels (`gemm`'s decoded sign block, `gemm_a8`'s
//! `xq`/`scales`/sign buffers, the blocked butterfly's transpose block)
//! into caller-retained scratch.  This binary wraps the global allocator
//! in a counting shim and asserts that, once the scratch has seen its
//! working shape, repeated kernel calls perform **zero** allocations —
//! including after the token count shrinks and grows back (resize stays
//! within capacity).
//!
//! §Perf iteration 8 widens the guard across the runtime ISA dispatch:
//! the steady-state window re-runs per force-selected kernel path
//! (scalar + whatever SIMD paths this machine has), covering the
//! W1.58A8 serving default's decode-path GEMM (`gemm_a8_with`:
//! quantize → sign decode → i8 tiles) on every path.  Dispatch itself
//! is one relaxed atomic load and `force_isa` one atomic store, so
//! path selection allocates nothing either.
//!
//! Lives in its own integration-test binary: `#[global_allocator]` is
//! process-wide and the counter must not see other tests' allocations
//! (which is also why everything stays in the one test fn — parallel
//! test threads would bleed counts into each other's windows).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use butterfly_moe::butterfly::Butterfly;
use butterfly_moe::expertcache::DecodedExpert;
use butterfly_moe::kernels::{dispatch, Isa, TernaryScratch};
use butterfly_moe::testutil;
use butterfly_moe::util::Rng;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_kernel_calls_do_not_allocate() {
    const ROWS: usize = 48;
    const COLS: usize = 128;
    const T_MAX: usize = 8;
    let sub = testutil::random_substrate(ROWS, COLS, 1);
    let dec = DecodedExpert::materialize(&sub);
    let mut rng = Rng::new(2);
    let bf = Butterfly::random(COLS, Butterfly::max_depth(COLS), 0.5, &mut rng);
    let x = testutil::normal_vec(T_MAX * COLS, 3);
    let mut xb = testutil::normal_vec(T_MAX * COLS, 4);
    let mut y = vec![0.0f32; T_MAX * ROWS];
    let mut scratch = TernaryScratch::default();
    let mut bscratch = Vec::new();

    // warmup: every scratch vector reaches its working shape once
    sub.gemm_with(&x, T_MAX, &mut y, &mut scratch);
    sub.gemm_a8_with(&x, T_MAX, &mut y, &mut scratch);
    dec.gemm(&x, T_MAX, &mut y);
    bf.apply_batch_with(&mut xb, &mut bscratch);

    // the guard holds per forced kernel path: scalar plus every SIMD
    // path this machine supports (unavailable ones are reported skips)
    for isa in Isa::ALL {
        if !isa.available() {
            eprintln!("SKIP: kernel ISA '{isa}' unavailable on this machine");
            continue;
        }
        // force_isa is one atomic store — no env read, no allocation —
        // so it can sit inside the measured window too
        dispatch::force_isa(isa).unwrap();
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        dispatch::force_isa(isa).unwrap();
        // steady state: shrink t, grow back, mix every kernel +
        // transpose; gemm_a8_with is the W1.58A8 serving default's
        // decode-path GEMM (quantize -> sign decode -> i8 tiles)
        for t in [T_MAX, 5, 1, 3, T_MAX] {
            sub.gemm_with(&x[..t * COLS], t, &mut y[..t * ROWS], &mut scratch);
            sub.gemm_a8_with(&x[..t * COLS], t, &mut y[..t * ROWS], &mut scratch);
            dec.gemm(&x[..t * COLS], t, &mut y[..t * ROWS]);
        }
        bf.apply_batch_with(&mut xb, &mut bscratch);
        bf.apply_transpose_batch_with(&mut xb, &mut bscratch);
        let after = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "isa={isa}: steady-state kernel calls must not allocate \
             ({} allocations observed)",
            after - before
        );
    }
}
