//! Cross-layer parity: the native Rust edge engine vs the AOT-compiled
//! jax graph (which embeds the L1 Pallas kernels) on identical weights.
//!
//! This is the repo's strongest correctness signal: three independent
//! implementations of eq. (2) — pure-jnp oracle (pytest), Pallas kernel
//! (inside the HLO), and the packed-ternary native engine — must agree.
//!
//! Skips (passes vacuously) when `make artifacts` hasn't run.

use std::path::PathBuf;

use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer};
use butterfly_moe::runtime::{Engine, Value};
use butterfly_moe::tensor::store::TensorStore;
use butterfly_moe::tensor::Tensor;
use butterfly_moe::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn native_engine_matches_aot_graph_on_moe_layer() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();

    // native layer from the exported ffn params
    let store = TensorStore::read(&dir.join("tiny.ffn.bmoe")).unwrap();
    let native = ButterflyMoeLayer::from_store(&store, "ffn.", cfg.top_k).unwrap();

    // identical input batch
    let t = 16usize;
    let d = cfg.d_model;
    let mut rng = Rng::new(1234);
    let x = Tensor::rand_normal(&[t, d], 1.0, &mut rng);

    // PJRT path: params + x -> (y, load)
    let mut inputs = engine.load_params("tiny.ffn").unwrap();
    inputs.push(Value::F32(x.clone()));
    let out = engine.run("tiny__moe_fwd_t16", &inputs).unwrap();
    let y_pjrt = out[0].as_f32().unwrap();
    let load_pjrt = out[1].as_f32().unwrap();

    // native path
    let mut y_native = vec![0.0f32; t * d];
    let loads_native = native.forward(&x.data, t, &mut y_native);

    // outputs agree (ternary substrate identical; fp noise only)
    let scale = y_pjrt
        .data
        .iter()
        .fold(0.0f32, |m, v| m.max(v.abs()))
        .max(1e-6);
    let mut max_err = 0.0f32;
    for (a, b) in y_native.iter().zip(&y_pjrt.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err / scale < 2e-3,
        "native vs pjrt max err {max_err} (scale {scale})"
    );

    // router load fractions agree
    for (a, b) in loads_native.iter().zip(&load_pjrt.data) {
        assert!((a - *b as f64).abs() < 1e-4, "loads {loads_native:?} vs {:?}", load_pjrt.data);
    }
}

#[test]
fn native_engine_matches_aot_on_all_token_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();
    let store = TensorStore::read(&dir.join("tiny.ffn.bmoe")).unwrap();
    let native = ButterflyMoeLayer::from_store(&store, "ffn.", cfg.top_k).unwrap();

    for bucket in [64usize, 256] {
        let name = format!("tiny__moe_fwd_t{bucket}");
        let mut rng = Rng::new(bucket as u64);
        let x = Tensor::rand_normal(&[bucket, cfg.d_model], 0.7, &mut rng);
        let mut inputs = engine.load_params("tiny.ffn").unwrap();
        inputs.push(Value::F32(x.clone()));
        let out = engine.run(&name, &inputs).unwrap();
        let y_pjrt = out[0].as_f32().unwrap();

        let mut y_native = vec![0.0f32; bucket * cfg.d_model];
        native.forward(&x.data, bucket, &mut y_native);
        let scale = y_pjrt.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        let max_err = y_native
            .iter()
            .zip(&y_pjrt.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err / scale < 2e-3, "bucket {bucket}: err {max_err}");
    }
}

#[test]
fn expert_bytes_scale_sublinearly_on_loaded_layer() {
    let Some(dir) = artifacts_dir() else { return };
    let store = TensorStore::read(&dir.join("tiny.ffn.bmoe")).unwrap();
    let layer = ButterflyMoeLayer::from_store(&store, "ffn.", 2).unwrap();
    // tiny: d=64, d_ff=256, 4 experts -> formula check
    let s = butterfly_moe::memmodel::LayerShape {
        d_model: 64,
        d_ff: 256,
    };
    let formula = butterfly_moe::memmodel::butterfly_bytes(4, s);
    let measured = layer.expert_bytes() as f64;
    assert!(
        (measured - formula).abs() / formula < 0.05,
        "measured {measured} vs formula {formula}"
    );
}
