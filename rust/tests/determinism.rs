//! Determinism harness for the expert-parallel hot path.
//!
//! The worker pool's contract (see `butterfly_moe::parallel`) is that
//! sharding never changes output bits: synthesis tasks write disjoint
//! dispatch blocks, and the reduction into `h` preserves the sequential
//! per-token accumulation order (ascending expert index) across disjoint
//! token-row shards.  This suite pins that end-to-end:
//!
//! * decoding the same seeded prompt set with workers ∈ {1, 2, 8} yields
//!   bitwise-identical token streams,
//! * `experts_forward` produces identical outputs *and* identical load
//!   vectors for every worker count,
//! * both hold with the expert-residency cache off and on (budgets
//!   {0, 2 MB = partial at this shape, all experts}), and across
//!   budgets too (cache parity),
//! * a panicking ("poisoned") expert fails the decode step with the
//!   panic payload instead of deadlocking the pool's condvar wait, and
//!   the pool remains serviceable afterwards,
//! * and the multi-layer model artifact composes with all of it: a
//!   packed 2-layer model decodes streams identical to the in-memory
//!   stack it was packed from, for every loader (mmap/heap) × worker
//!   count × cache budget × prefill chunk size ({1, 4, all} — chunked
//!   prompt ingestion never changes decoded bits, DESIGN.md §2/§3).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use butterfly_moe::artifact::{synthesize, LoadMode, Mmap, ModelArtifact, SynthSpec};
use butterfly_moe::coordinator::{
    collect_stream, warm, Backend, Coordinator, GenerateRequest, InflightBatch, InflightSeq,
    NativeLmBackend, NativeMoeBackend, SamplingParams, SchedulerConfig,
};
use butterfly_moe::expertcache::{decoded_expert_bytes, ExpertCacheConfig};
use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer};
use butterfly_moe::parallel::WorkerPool;
use butterfly_moe::testutil;

const D: usize = 128;
const DFF: usize = 512;
const E: usize = 16;
const TOP_K: usize = 2;
const LAYER_SEED: u64 = 0xDE7;
const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

/// Cache budgets under test: off, 2 MB (partial residency at this
/// shape: one working set is ~256 KB, so 2 MB holds 7 of 16 experts and
/// forces miss/admission churn), and the full expert set.
fn budgets() -> [usize; 3] {
    let entry = decoded_expert_bytes(DFF, D);
    let two_mb = 2 * 1024 * 1024;
    assert!(
        two_mb / entry > 0 && two_mb / entry < E,
        "2 MB must be partial residency at this shape ({} per expert)",
        entry
    );
    [0, two_mb, E * entry]
}

fn build_layer(workers: usize, budget_bytes: usize) -> ButterflyMoeLayer {
    let mut layer = testutil::butterfly_layer(D, DFF, E, TOP_K, LAYER_SEED);
    layer.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
    if budget_bytes > 0 {
        layer.attach_expert_cache(ExpertCacheConfig::with_budget_bytes(budget_bytes));
    }
    layer
}

/// Fixed seeded prompt set: a mix of greedy and seeded-temperature
/// sessions with different lengths, so the decode loop exercises
/// batching, sampling, and routing variety.
fn prompt_set() -> Vec<GenerateRequest> {
    (0..6)
        .map(|i| {
            let prompt: Vec<i32> = (0..4 + i % 3)
                .map(|j| ((i * 97 + j * 31) % 512) as i32)
                .collect();
            let req = GenerateRequest::greedy(prompt, 10);
            if i % 3 == 2 {
                req.with_sampling(SamplingParams::top_k(0.8, 40, 1000 + i as u64))
            } else {
                req
            }
        })
        .collect()
}

fn decode_streams(workers: usize, budget_bytes: usize) -> Vec<Vec<i32>> {
    let layer = Arc::new(build_layer(workers, budget_bytes));
    let backend = Arc::new(NativeMoeBackend::new(layer, 512, 32, 8));
    warm(backend.as_ref()).unwrap();
    // max_batch equal to the session count plus a generous admission
    // window keeps the decode-step composition identical across runs:
    // the first batch starts as soon as all six sessions have joined
    // (they are submitted within microseconds of each other), and equal
    // token budgets retire them together — so the bitwise comparison
    // below never hinges on scheduler timing.
    let coord = Coordinator::start(backend, SchedulerConfig::new(6, Duration::from_millis(200)));
    let rxs: Vec<_> = prompt_set().into_iter().map(|r| coord.submit(r)).collect();
    let streams = rxs
        .into_iter()
        .map(|rx| collect_stream(&rx, Duration::from_secs(60)).unwrap().tokens)
        .collect();
    coord.shutdown();
    streams
}

#[test]
fn token_streams_bitwise_identical_across_workers_and_budgets() {
    let reference = decode_streams(WORKER_COUNTS[0], 0);
    assert!(reference.iter().all(|s| !s.is_empty()));
    for budget in budgets() {
        for workers in WORKER_COUNTS {
            let streams = decode_streams(workers, budget);
            assert_eq!(
                streams, reference,
                "workers={workers} budget={budget}: token streams diverged"
            );
        }
    }
}

/// Observability must be determinism-neutral: `--trace-sample 1` (every
/// occurrence timed — the most invasive setting) yields token streams
/// bitwise identical to tracing off.  Timers only read the clock and
/// write a side registry (DESIGN.md §7), so the decoded bits cannot
/// depend on the sample rate.
#[test]
fn token_streams_bitwise_identical_with_tracing_on() {
    use butterfly_moe::obs::trace;
    // trace state is process-global; serialize with other mutating tests
    let _g = trace::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
    trace::set_sample(0);
    trace::reset();
    let reference = decode_streams(2, 0);
    assert!(reference.iter().all(|s| !s.is_empty()));
    assert!(
        trace::snapshot().is_empty(),
        "sample 0 must record nothing"
    );
    trace::set_sample(1);
    let traced = decode_streams(2, 0);
    let stages = trace::snapshot();
    trace::set_sample(0);
    trace::reset();
    assert_eq!(traced, reference, "tracing at sample 1 changed decoded bits");
    // the run above must actually have exercised the instrumentation —
    // a vacuous pass (timers compiled out / never hit) is a test bug
    assert!(
        stages.iter().any(|s| s.stage == trace::Stage::TernaryGemm && s.hist.n > 0),
        "no ternary-GEMM samples recorded: {stages:?}"
    );
    assert!(
        stages.iter().any(|s| s.stage == trace::Stage::SchedStep && s.hist.n > 0),
        "no scheduler-step samples recorded: {stages:?}"
    );
    assert!(
        stages.iter().any(|s| s.stage == trace::Stage::Prefill && s.hist.n > 0),
        "no prefill samples recorded: {stages:?}"
    );
}

#[test]
fn experts_forward_outputs_and_load_vectors_identical_across_workers() {
    let x = testutil::normal_vec(11 * D, 0x5EED);
    for budget in budgets() {
        let mut want_h: Option<Vec<f32>> = None;
        let mut want_loads: Option<Vec<f64>> = None;
        for workers in WORKER_COUNTS {
            let layer = build_layer(workers, budget);
            if let Some(c) = layer.expert_cache() {
                c.prewarm(); // fill the budget so the fast path is hit
            }
            // several forwards so cached runs mix hits and misses under
            // the partial budget while ticks churn residency
            let mut h = vec![0.0f32; 11 * DFF];
            let mut loads = Vec::new();
            for _ in 0..4 {
                loads = layer.experts_forward(&x, 11, &mut h);
                if let Some(c) = layer.expert_cache() {
                    c.tick();
                }
            }
            if let (Some(wh), Some(wl)) = (&want_h, &want_loads) {
                assert_eq!(&h, wh, "workers={workers} budget={budget}: outputs");
                assert_eq!(&loads, wl, "workers={workers} budget={budget}: load vectors");
            } else {
                want_h = Some(h);
                want_loads = Some(loads);
            }
        }
    }
}

#[test]
fn full_forward_identical_across_workers() {
    // covers the row-sharded down projection on top of the mixture
    let x = testutil::normal_vec(7 * D, 0xF00D);
    let mut want = vec![0.0f32; 7 * D];
    build_layer(1, 0).forward(&x, 7, &mut want);
    for workers in WORKER_COUNTS {
        let mut y = vec![0.0f32; 7 * D];
        build_layer(workers, 0).forward(&x, 7, &mut y);
        assert_eq!(y, want, "workers={workers}");
    }
}

// ---------------------------------------------------------------------------
// Multi-layer packed model (the artifact subsystem's determinism story)
// ---------------------------------------------------------------------------

/// Stream the prompt set through a coordinator over `backend`, with
/// prompts ingested in `prefill_chunk`-token bites (0 = all at once).
fn streams_of(backend: Arc<NativeLmBackend>, prefill_chunk: usize) -> Vec<Vec<i32>> {
    warm(backend.as_ref()).unwrap();
    let coord = Coordinator::start(
        backend,
        SchedulerConfig::new(6, Duration::from_millis(200)).with_prefill_chunk(prefill_chunk),
    );
    let n_sessions = prompt_set().len() as u64;
    let rxs: Vec<_> = prompt_set().into_iter().map(|r| coord.submit(r)).collect();
    let streams = rxs
        .into_iter()
        .map(|rx| collect_stream(&rx, Duration::from_secs(60)).unwrap().tokens)
        .collect();
    // TTFT fires once per session, on the first *decoded* token — never
    // per prefill chunk (the non-vacuous check: ttft_count would read
    // high under chunked prefill if mid-prefill steps recorded it)
    let snap = coord.metrics.snapshot();
    assert_eq!(
        snap.ttft_count, n_sessions,
        "chunk {prefill_chunk}: TTFT must be recorded exactly once per session"
    );
    coord.shutdown();
    streams
}

/// A packed 2-layer model must decode the exact token streams of the
/// in-memory model it was packed from — for every load mode (mmap /
/// heap), worker count, cache budget, **and prefill chunk size**
/// ({1, 4, all}: a prompt prefilled one token at a time, in 4-token
/// bites, or all at once decodes the bit-identical stream — chunking
/// changes *when* rows enter the pooled state, never the float
/// association of a step, DESIGN.md §2).  This is the multi-layer
/// extension of the single-layer invariants above, and the acceptance
/// gate of `bmoe pack-model` + `bmoe serve --native --model`.
#[test]
fn packed_multi_layer_streams_identical_across_loaders_workers_budgets() {
    let spec = SynthSpec {
        d_model: 64,
        d_ff: 256,
        n_experts: 8,
        top_k: 2,
        n_layers: 2,
        vocab: 512,
        seq_len: 32,
        depth: None,
        seed: 0x9AC5,
    };
    let model = synthesize(&spec);
    let dir = std::env::temp_dir().join("bmoe_determinism_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lm2.bmoe");
    model.pack(&path).unwrap();
    // reference: the in-memory stack, sequential, uncached, all-at-once
    let reference = streams_of(Arc::new(NativeLmBackend::from_synth(model, 8, None, 0)), 0);
    assert!(reference.iter().all(|s| !s.is_empty()));

    let modes = if Mmap::supported() {
        vec![LoadMode::Heap, LoadMode::Mmap]
    } else {
        vec![LoadMode::Heap]
    };
    // partial residency: 3 of 8 experts per layer (budget splits evenly)
    let partial = 2 * 3 * decoded_expert_bytes(spec.d_ff, spec.d_model);
    for mode in modes {
        for workers in [1usize, 8] {
            for budget in [0usize, partial] {
                for chunk in [1usize, 4, 0] {
                    let artifact = ModelArtifact::load(&path, mode).unwrap();
                    let backend = NativeLmBackend::from_artifact(
                        &artifact,
                        8,
                        Some(Arc::new(WorkerPool::new(workers))),
                        budget,
                    )
                    .unwrap();
                    let streams = streams_of(Arc::new(backend), chunk);
                    assert_eq!(
                        streams, reference,
                        "{} load, workers={workers}, budget={budget}, \
                         prefill_chunk={chunk}: token streams diverged from the \
                         in-memory model",
                        mode.name()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// W1.58A8 serving default (§Perf iteration 8)
// ---------------------------------------------------------------------------

/// `--exact` is the compatibility contract of the A8 flip: a backend
/// built with `act_quant = false` (what `serve --exact` requests) must
/// decode token streams bitwise identical to the pre-A8 default
/// constructor, across loaders {mmap, heap} × workers {1, 8}.  And the
/// A8 default keeps the determinism story: its streams are bitwise
/// identical to *each other* across the same matrix (quantization
/// changes the numbers once, not per-schedule).
#[test]
fn exact_mode_streams_match_pre_a8_default_across_loaders_and_workers() {
    let spec = SynthSpec {
        d_model: 64,
        d_ff: 256,
        n_experts: 8,
        top_k: 2,
        n_layers: 2,
        vocab: 512,
        seq_len: 32,
        depth: None,
        seed: 0x9AC5,
    };
    let model = synthesize(&spec);
    let dir = std::env::temp_dir().join("bmoe_determinism_a8");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lm2_a8.bmoe");
    model.pack(&path).unwrap();
    // the pre-A8 default: the plain constructor (exact f32 synthesis)
    let pre_a8 = streams_of(Arc::new(NativeLmBackend::from_synth(model, 8, None, 0)), 0);
    assert!(pre_a8.iter().all(|s| !s.is_empty()));
    let modes = if Mmap::supported() {
        vec![LoadMode::Heap, LoadMode::Mmap]
    } else {
        vec![LoadMode::Heap]
    };
    let mut a8_reference: Option<Vec<Vec<i32>>> = None;
    for mode in modes {
        for workers in [1usize, 8] {
            for act_quant in [false, true] {
                let artifact = ModelArtifact::load(&path, mode).unwrap();
                let backend = NativeLmBackend::from_artifact_opts(
                    &artifact,
                    8,
                    Some(Arc::new(WorkerPool::new(workers))),
                    0,
                    act_quant,
                )
                .unwrap();
                let streams = streams_of(Arc::new(backend), 0);
                if !act_quant {
                    assert_eq!(
                        streams,
                        pre_a8,
                        "{} load, workers={workers}: --exact streams diverged from \
                         the pre-A8 default",
                        mode.name()
                    );
                } else {
                    match &a8_reference {
                        Some(want) => assert_eq!(
                            &streams,
                            want,
                            "{} load, workers={workers}: A8 streams not \
                             schedule-invariant",
                            mode.name()
                        ),
                        None => a8_reference = Some(streams),
                    }
                }
            }
        }
    }
}

/// The accuracy gate of the A8 serving flip: on the checked-in
/// cross-language fixture, the W1.58A8 path's logits stay within a
/// small relative bound of the exact f32 path's — and the test proves
/// the quantized path actually ran (`dispatch::a8_gemm_calls`), so a
/// silent fallback to the exact path cannot pass it vacuously.
#[test]
fn a8_default_logit_error_bounded_on_fixture() {
    use butterfly_moe::kernels::dispatch;
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/tiny_model.bmoe");
    assert!(
        path.exists(),
        "missing fixture {} (regenerate with python3 python/tests/make_artifact_fixture.py)",
        path.display()
    );
    let artifact = ModelArtifact::load(&path, LoadMode::Heap).unwrap();
    let vocab = artifact.manifest.vocab;
    // rebuild the fixture's prompt set from its expected.* tensors
    let (pshape, prompts_flat) = artifact.store().i32("expected.prompts").unwrap();
    let (_, lens) = artifact.store().i32("expected.prompt_lens").unwrap();
    let width = pshape[1];
    let prompts: Vec<Vec<i32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| prompts_flat[i * width..i * width + n as usize].to_vec())
        .collect();
    let batch_of = |prompts: &[Vec<i32>]| {
        let mut b = InflightBatch::new();
        for (i, p) in prompts.iter().enumerate() {
            b.push(InflightSeq::new(i as u64, p.clone()));
        }
        b
    };
    let logits_of = |act_quant: bool| -> Vec<Vec<f32>> {
        let backend =
            NativeLmBackend::from_artifact_opts(&artifact, 8, None, 0, act_quant).unwrap();
        backend
            .step(&mut batch_of(&prompts))
            .unwrap()
            .into_iter()
            .map(|o| o.logits.expect("all-at-once prefill emits logits"))
            .collect()
    };
    let calls_before_exact = dispatch::a8_gemm_calls();
    let exact = logits_of(false);
    let calls_after_exact = dispatch::a8_gemm_calls();
    assert_eq!(
        calls_after_exact, calls_before_exact,
        "the exact path must not run A8 substrate GEMMs"
    );
    let a8 = logits_of(true);
    assert!(
        dispatch::a8_gemm_calls() > calls_after_exact,
        "the A8 path never ran an A8 substrate GEMM — the accuracy gate is vacuous"
    );
    let scale = exact.iter().flatten().fold(0.0f32, |acc, v| acc.max(v.abs()));
    assert!(scale > 0.0);
    let mut max_rel = 0.0f32;
    for (i, (got_row, want_row)) in a8.iter().zip(&exact).enumerate() {
        assert_eq!(got_row.len(), vocab);
        for (j, (&got, &want)) in got_row.iter().zip(want_row).enumerate() {
            let rel = (got - want).abs() / scale;
            assert!(
                rel < 5e-2,
                "prompt {i} logit {j}: A8 {got} vs exact {want} (rel {rel:.4} > 5e-2)"
            );
            max_rel = max_rel.max(rel);
        }
    }
    // per-token absmax quantization perturbs every logit a little; a
    // bitwise-identical result would mean the exact path ran instead
    assert!(max_rel > 0.0, "A8 logits bitwise equal to exact — quantization never happened");
}

/// Find an expert the probe batch actually routes to, so poisoning it
/// is guaranteed to fire.
fn routed_expert(layer: &ButterflyMoeLayer, x: &[f32], t: usize) -> usize {
    let mut h = vec![0.0f32; t * DFF];
    let loads = layer.experts_forward(x, t, &mut h);
    loads.iter().position(|&l| l > 0.0).expect("some expert is routed")
}

#[test]
fn poisoned_expert_fails_step_with_payload_and_pool_recovers() {
    let pool = Arc::new(WorkerPool::new(4));
    let mut layer = testutil::butterfly_layer(D, DFF, E, TOP_K, LAYER_SEED);
    layer.attach_worker_pool(pool.clone());
    let x = testutil::normal_vec(5 * D, 0xBAD);
    layer.poison_expert = Some(routed_expert(&layer, &x, 5));
    // the decode step must fail by *panicking with the payload* — and
    // must return (no condvar deadlock on the dead task)
    let err = catch_unwind(AssertUnwindSafe(|| {
        let mut h = vec![0.0f32; 5 * DFF];
        layer.experts_forward(&x, 5, &mut h);
    }))
    .expect_err("poisoned expert must fail the decode step");
    let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
    assert!(msg.contains("poisoned expert"), "payload: {msg}");
    // same pool, fresh step: the pool survived the panic
    layer.poison_expert = None;
    let mut h = vec![0.0f32; 5 * DFF];
    layer.experts_forward(&x, 5, &mut h);
    assert!(h.iter().any(|&v| v != 0.0));
}

#[test]
#[should_panic(expected = "poisoned expert")]
fn poisoned_expert_panics_through_backend_step() {
    // Poison each expert in turn; the batch's top-k routing hits at
    // least TOP_K of them, and the first hit must unwind out of
    // `Backend::step` with its payload — re-raised here so the harness
    // matches it.  If no expert fired, the trailing panic's different
    // message fails the `expected` check (nothing routed = a gating
    // regression, not a pass).
    let prompts = vec![vec![1, 2, 3], vec![9, 9, 9]];
    for e in 0..E {
        let mut layer = testutil::butterfly_layer(D, DFF, E, TOP_K, LAYER_SEED);
        layer.attach_worker_pool(Arc::new(WorkerPool::new(2)));
        layer.poison_expert = Some(e);
        let backend = NativeMoeBackend::new(Arc::new(layer), 512, 32, 8);
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| {
            let _ = butterfly_moe::coordinator::greedy_next(&backend, &prompts);
        })) {
            std::panic::resume_unwind(payload);
        }
    }
    panic!("probe batch routed to no expert at all");
}
