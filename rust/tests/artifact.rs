//! Model-artifact integration suite (DESIGN.md §3):
//!
//! * pack → load round trip: a packed model served through
//!   [`NativeLmBackend`] produces logits **bit-identical** to the
//!   in-memory model it was packed from, across mmap-vs-heap loading,
//!   `--workers` ∈ {1, 8}, and expert-cache budgets {0, partial} — the
//!   acceptance invariant of the artifact subsystem.
//! * cross-language: the checked-in `tiny_model.bmoe` fixture (written
//!   by `python/tests/make_artifact_fixture.py` through the normative
//!   python writer) loads through both loaders, which agree bitwise,
//!   and its logits pin against the fixture's numpy-computed
//!   `expected.logits` within a float tolerance (structural drift —
//!   wrong stage order, wrong bitplane layout — lands far outside it).
//! * file-bytes accounting: `memmodel::model_file_bytes` brackets the
//!   real packed size.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use butterfly_moe::artifact::{synthesize, LoadMode, Mmap, ModelArtifact, SynthSpec};
use butterfly_moe::coordinator::{Backend, InflightBatch, InflightSeq, NativeLmBackend};
use butterfly_moe::expertcache::decoded_expert_bytes;
use butterfly_moe::moe::MoeLayer;
use butterfly_moe::parallel::WorkerPool;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("bmoe_artifact_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn load_modes() -> Vec<LoadMode> {
    if Mmap::supported() {
        vec![LoadMode::Heap, LoadMode::Mmap]
    } else {
        vec![LoadMode::Heap]
    }
}

fn spec() -> SynthSpec {
    SynthSpec {
        d_model: 32,
        d_ff: 64,
        n_experts: 8,
        top_k: 2,
        n_layers: 2,
        vocab: 64,
        seq_len: 16,
        depth: None,
        seed: 0xA57,
    }
}

fn probe_batch() -> InflightBatch {
    let mut b = InflightBatch::new();
    for i in 0..5i64 {
        b.push(InflightSeq::new(
            i as u64,
            (0..3 + i % 3).map(|j| ((i * 97 + j * 31) % 64) as i32).collect(),
        ));
    }
    b
}

fn step_logits(backend: &NativeLmBackend) -> Vec<Vec<f32>> {
    // several steps with cache ticks in between, so budgeted runs mix
    // admissions, hits and misses before the compared step
    for _ in 0..3 {
        backend.step(&mut probe_batch()).unwrap();
        backend.tick_caches();
    }
    backend
        .step(&mut probe_batch())
        .unwrap()
        .into_iter()
        .map(|o| o.logits.expect("all-at-once prefill emits logits"))
        .collect()
}

#[test]
fn packed_model_bit_identical_to_in_memory_across_loaders_workers_budgets() {
    let spec = spec();
    let model = synthesize(&spec);
    let path = tmp("roundtrip_it.bmoe");
    model.pack(&path).unwrap();
    // reference: the in-memory model, sequential, no cache
    let reference = step_logits(&NativeLmBackend::from_synth(model, 8, None, 0));
    assert!(reference.iter().all(|l| l.iter().all(|v| v.is_finite())));
    // partial residency: 3 of 8 experts per layer fit (budget splits
    // evenly across the 2 layers)
    let entry = decoded_expert_bytes(spec.d_ff, spec.d_model);
    let partial = 2 * 3 * entry;
    for mode in load_modes() {
        for workers in [1usize, 8] {
            for budget in [0usize, partial] {
                let artifact = ModelArtifact::load(&path, mode).unwrap();
                let backend = NativeLmBackend::from_artifact(
                    &artifact,
                    8,
                    Some(Arc::new(WorkerPool::new(workers))),
                    budget,
                )
                .unwrap();
                if budget > 0 {
                    let cache = backend.layers()[0].expert_cache().expect("cache attached");
                    assert!(cache.enabled(), "partial budget must enable the cache");
                    assert!(
                        cache.capacity_experts() < spec.n_experts,
                        "budget must be partial, not all-resident"
                    );
                    backend.prewarm_caches();
                }
                let got = step_logits(&backend);
                assert_eq!(
                    got, reference,
                    "{} load, workers={workers}, budget={budget}: logits diverged \
                     from the in-memory model",
                    mode.name()
                );
            }
        }
    }
}

#[test]
fn packed_model_greedy_streams_match_in_memory() {
    // token-level view of the same invariant, through greedy_next
    let spec = spec();
    let model = synthesize(&spec);
    let path = tmp("greedy_it.bmoe");
    model.pack(&path).unwrap();
    let prompts: Vec<Vec<i32>> = (0..7).map(|i| vec![i, i + 9, (i * 13) % 64]).collect();
    let reference = {
        // max_batch 4, smaller than the prompt set: exercises chunked steps
        let backend = NativeLmBackend::from_synth(model, 4, None, 0);
        butterfly_moe::coordinator::greedy_next(&backend, &prompts).unwrap()
    };
    for mode in load_modes() {
        let artifact = ModelArtifact::load(&path, mode).unwrap();
        let backend = NativeLmBackend::from_artifact(&artifact, 4, None, 0).unwrap();
        let got = butterfly_moe::coordinator::greedy_next(&backend, &prompts).unwrap();
        assert_eq!(got, reference, "{} load: greedy tokens diverged", mode.name());
    }
}

// ---------------------------------------------------------------------------
// Cross-language fixture
// ---------------------------------------------------------------------------

fn fixture_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/tiny_model.bmoe")
}

/// Rebuild the fixture's prompt set from its `expected.*` tensors.
fn fixture_prompts(artifact: &ModelArtifact) -> Vec<Vec<i32>> {
    let (pshape, prompts) = artifact.store().i32("expected.prompts").unwrap();
    let (_, lens) = artifact.store().i32("expected.prompt_lens").unwrap();
    let width = pshape[1];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| prompts[i * width..i * width + n as usize].to_vec())
        .collect()
}

#[test]
fn python_fixture_loads_and_pins_logits() {
    let path = fixture_path();
    assert!(
        path.exists(),
        "missing fixture {} (regenerate with python3 python/tests/make_artifact_fixture.py)",
        path.display()
    );
    let mut per_mode: Vec<Vec<Vec<f32>>> = Vec::new();
    for mode in load_modes() {
        let artifact = ModelArtifact::load(&path, mode).unwrap();
        let m = &artifact.manifest;
        assert_eq!((m.n_layers, m.n_experts, m.top_k), (2, 4, 2));
        assert_eq!((m.d_model, m.d_ff, m.vocab, m.seq_len), (16, 32, 32, 16));
        let backend = NativeLmBackend::from_artifact(&artifact, 8, None, 0).unwrap();
        assert_eq!(backend.file_bytes(), artifact.file_bytes());
        assert!(backend.name().starts_with("native-lm:2blk:4exp:"), "{}", backend.name());

        let prompts = fixture_prompts(&artifact);
        let (lshape, want) = {
            let (s, t) = artifact.store().f32("expected.logits").unwrap();
            (s, t.as_slice().to_vec())
        };
        assert_eq!(lshape, vec![prompts.len(), m.vocab]);
        let (_, want_tokens) = artifact.store().i32("expected.next_tokens").unwrap();

        let mut batch = InflightBatch::new();
        for (i, p) in prompts.iter().enumerate() {
            batch.push(InflightSeq::new(i as u64, p.clone()));
        }
        let out = backend.step(&mut batch).unwrap();
        let scale = want.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let mut logits_per_prompt = Vec::new();
        for (i, o) in out.iter().enumerate() {
            let logits = o.logits.as_ref().expect("all-at-once prefill emits logits");
            let row = &want[i * m.vocab..(i + 1) * m.vocab];
            for (j, (&got, &exp)) in logits.iter().zip(row).enumerate() {
                assert!(
                    (got - exp).abs() / scale < 1e-3,
                    "{} load, prompt {i} logit {j}: got {got}, python reference {exp}",
                    mode.name()
                );
            }
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(
                argmax as i32,
                want_tokens[i],
                "{} load, prompt {i}: decoded token diverged from the python reference",
                mode.name()
            );
            logits_per_prompt.push(logits.clone());
        }
        per_mode.push(logits_per_prompt);
    }
    // mmap and heap loading of the SAME (python-written, pad-free,
    // possibly misaligned) file must agree bit-for-bit
    if per_mode.len() == 2 {
        assert_eq!(per_mode[0], per_mode[1], "heap vs mmap logits bits diverged");
    }
}

// ---------------------------------------------------------------------------
// File-bytes accounting
// ---------------------------------------------------------------------------

#[test]
fn packed_file_bytes_match_memmodel_accounting() {
    use butterfly_moe::memmodel::{model_file_bytes, LayerShape};
    let spec = SynthSpec {
        d_model: 64,
        d_ff: 256,
        n_experts: 8,
        top_k: 2,
        n_layers: 3,
        vocab: 128,
        seq_len: 16,
        depth: None,
        seed: 3,
    };
    let model = synthesize(&spec);
    let path = tmp("accounting.bmoe");
    let stats = model.pack(&path).unwrap();
    let payload = model_file_bytes(
        spec.n_layers,
        spec.n_experts,
        LayerShape {
            d_model: spec.d_model,
            d_ff: spec.d_ff,
        },
        spec.vocab,
    );
    let actual = stats.file_bytes as f64;
    assert!(
        actual >= payload,
        "file smaller than its own payload accounting: {actual} < {payload}"
    );
    // headers + manifest + alignment pads: bounded, small slack
    let slack = 8192.0 + stats.tensors as f64 * 128.0;
    assert!(
        actual <= payload + slack,
        "file overhead beyond accounting slack: {actual} vs {payload} + {slack}"
    );
    // and the loaded artifact reports exactly the on-disk size
    let artifact = ModelArtifact::load(&path, LoadMode::Heap).unwrap();
    assert_eq!(artifact.file_bytes() as u64, stats.file_bytes);
    assert_eq!(
        stats.file_bytes,
        std::fs::metadata(&path).unwrap().len()
    );
}
