//! Chaos suite: seeded fault schedules against a live `route` stack
//! (DESIGN.md §8).
//!
//! Every test drives the real router — admission, placement, relay,
//! failover, health/restart — over hermetic in-process workers, with a
//! deterministic fault plan installed via [`butterfly_moe::faults`].
//! The invariants pinned here are the robustness contract:
//!
//! * every accepted session ends in exactly one terminal event (an
//!   `END`/`ERR` line followed by clean EOF — never a hang, never a
//!   second terminal);
//! * sessions that complete through failover are bit-identical to a
//!   fault-free run of the same request;
//! * once the fault plan is cleared, the fleet returns to full healthy
//!   capacity and serves again.
//!
//! The fault plan is process-global, so tests that install one
//! serialize on a local mutex.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use butterfly_moe::faults::{clear, install, FaultPlan};
use butterfly_moe::router::{worker::InProcessLauncher, Router, RouterConfig};

/// Serializes the tests in this binary: the fault plan is one global.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn base_cfg() -> RouterConfig {
    RouterConfig {
        port: 0,
        fleet: 2,
        sessions_per_worker: 4,
        max_queue: 8,
        client_cap: 0,
        health_interval: Duration::from_millis(30),
        backoff_base: Duration::from_millis(20),
        backoff_cap: Duration::from_millis(500),
        queue_timeout: Duration::from_secs(10),
        drain_timeout: Duration::from_secs(30),
        failover_retries: 5,
        failover_wait: Duration::from_secs(20),
        ..RouterConfig::default()
    }
}

fn start(cfg: RouterConfig, launcher: InProcessLauncher) -> (Arc<Router>, SocketAddr) {
    let fleet = cfg.fleet;
    let router = Router::start(cfg, Arc::new(launcher)).unwrap();
    let (listener, addr) = butterfly_moe::util::net::listen_reuse(0).unwrap();
    {
        let router = router.clone();
        std::thread::spawn(move || router.serve(listener));
    }
    assert_eq!(router.fleet.healthy(), fleet, "fleet must boot fully");
    (router, addr)
}

/// Run one session and assert the exactly-one-terminal contract: the
/// stream is TOK lines, then ONE terminal (`END`/`ERR`), then clean EOF
/// (a trailing QUIT closes the connection).  Returns (tokens, terminal).
fn run_to_single_terminal(addr: SocketAddr, gen: &str) -> (Vec<i32>, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "{gen}\nQUIT\n").unwrap();
    let mut r = BufReader::new(s);
    let mut toks = Vec::new();
    let terminal = loop {
        let mut line = String::new();
        let n = r.read_line(&mut line).unwrap_or(0);
        assert!(n > 0, "EOF before any terminal line (tokens so far: {toks:?})");
        if let Some(rest) = line.strip_prefix("TOK ") {
            toks.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
        } else {
            break line.trim().to_string();
        }
    };
    let mut extra = String::new();
    assert_eq!(
        r.read_line(&mut extra).unwrap_or(0),
        0,
        "exactly one terminal event per session; got extra line {extra:?} after {terminal:?}"
    );
    (toks, terminal)
}

fn wait_full_capacity(router: &Router, fleet: usize, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.fleet.healthy() != fleet {
        assert!(
            Instant::now() < deadline,
            "{what}: fleet never returned to full capacity ({}/{fleet} healthy)",
            router.fleet.healthy()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// A seeded kill schedule: the first three sessions each lose their
/// placed worker mid-stream (SIGKILL after 6 relayed tokens).  Every
/// session must still end in exactly one `END max_tokens` terminal with
/// a token stream bit-identical to the fault-free baseline — failover
/// absorbs every kill — and the fleet returns to full capacity once the
/// plan is cleared.
#[test]
fn seeded_kill_schedule_completes_every_session_bit_identically() {
    let _g = lock();
    clear();
    let cfg = RouterConfig { fleet: 3, ..base_cfg() };
    let (router, addr) = start(cfg, InProcessLauncher::new(Duration::from_millis(5), 4));
    let gen = "GEN 24 0 0 0 -1 1 2";
    // fault-free baseline (CountBackend streams are deterministic in the
    // request, so this is the bit-identity reference)
    let (baseline, base_end) = run_to_single_terminal(addr, gen);
    assert_eq!(baseline.len(), 24);
    assert!(base_end.starts_with("END max_tokens 24 "), "{base_end}");
    install(FaultPlan {
        seed: 11,
        kill_after: 6,
        kill_prob: 1.0,
        kill_limit: 3,
        ..FaultPlan::default()
    });
    for i in 0..8 {
        let (toks, end) = run_to_single_terminal(addr, gen);
        assert_eq!(toks, baseline, "session {i}: stream must be bit-identical through faults");
        assert!(end.starts_with("END max_tokens 24 "), "session {i}: no ERR, got {end}");
    }
    clear();
    use std::sync::atomic::Ordering;
    assert_eq!(router.stats.worker_lost.load(Ordering::Relaxed), 0, "failover absorbed kills");
    assert_eq!(router.stats.replay_diverged.load(Ordering::Relaxed), 0);
    assert!(
        router.stats.failovers.load(Ordering::Relaxed) >= 3,
        "three kills fired => at least three failovers, got {}",
        router.stats.failovers.load(Ordering::Relaxed)
    );
    wait_full_capacity(&router, 3, "after kill schedule");
    let (toks, end) = run_to_single_terminal(addr, gen);
    assert_eq!(toks, baseline);
    assert!(end.starts_with("END max_tokens 24 "), "{end}");
    router.drain();
}

/// While every launch attempt fails (spawn_fail=1), a killed worker
/// stays down — and the moment the plan clears, the health loop's next
/// relaunch sticks and sessions flow again.
#[test]
fn spawn_failures_block_restart_until_the_plan_clears() {
    let _g = lock();
    clear();
    let cfg = RouterConfig { fleet: 1, ..base_cfg() };
    let (router, addr) = start(cfg, InProcessLauncher::new(Duration::ZERO, 4));
    let (toks, _) = run_to_single_terminal(addr, "GEN 2 0 0 0 -1 1 2");
    assert_eq!(toks.len(), 2);
    install(FaultPlan { spawn_fail: 1.0, ..FaultPlan::default() });
    router.kill_worker(0);
    // the health loop notices the death and retries the launch, but
    // every attempt is injected to fail
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.fleet.healthy() != 0 {
        assert!(Instant::now() < deadline, "killed worker never marked down");
        std::thread::sleep(Duration::from_millis(10));
    }
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(router.fleet.healthy(), 0, "no launch may succeed under spawn_fail=1");
    clear();
    wait_full_capacity(&router, 1, "after spawn failures");
    let (toks, end) = run_to_single_terminal(addr, "GEN 2 0 0 0 -1 1 2");
    assert_eq!(toks.len(), 2, "{end}");
    router.drain();
}

/// A stalled worker (answers nothing, holds its sockets) must trip the
/// relay's read timeout and produce a bounded terminal ERR — never a
/// hung client — and the fleet must recover once the stall clears.
#[test]
fn stalled_workers_give_bounded_err_and_fleet_recovers() {
    let _g = lock();
    clear();
    let cfg = RouterConfig {
        fleet: 2,
        failover_retries: 1,
        failover_wait: Duration::from_secs(1),
        relay_read_timeout: Duration::from_millis(250),
        ..base_cfg()
    };
    let (router, addr) = start(cfg, InProcessLauncher::new(Duration::ZERO, 4));
    let gen = "GEN 6 0 0 0 -1 1 2";
    let (baseline, _) = run_to_single_terminal(addr, gen);
    assert_eq!(baseline.len(), 6);
    // every wire line (GEN relays and STATS health polls alike) now
    // stalls far past the relay read timeout
    install(FaultPlan { stall_ms: 3_000, stall_prob: 1.0, ..FaultPlan::default() });
    let t0 = Instant::now();
    let (toks, end) = run_to_single_terminal(addr, gen);
    assert!(toks.is_empty(), "stalled workers streamed tokens? {toks:?}");
    assert!(end.starts_with("ERR"), "bounded terminal error, got {end}");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "stall must be bounded by timeouts, took {:?}",
        t0.elapsed()
    );
    clear();
    // restarted workers answer polls again; sessions flow and match
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if router.fleet.healthy() > 0 {
            let (toks, end) = run_to_single_terminal(addr, gen);
            if end.starts_with("END max_tokens 6 ") {
                assert_eq!(toks, baseline, "post-recovery stream must match baseline");
                break;
            }
        }
        assert!(Instant::now() < deadline, "fleet never recovered from stalls");
        std::thread::sleep(Duration::from_millis(100));
    }
    wait_full_capacity(&router, 2, "after stalls");
    router.drain();
}

/// A corrupted inbound `GEN` line on the worker is always parse-visible
/// (never a silently different request): the session ends in exactly
/// one clean `ERR bad request` terminal, no tokens, and the fleet keeps
/// serving untouched once the plan clears.
#[test]
fn corrupted_wire_line_is_one_clean_error_terminal() {
    let _g = lock();
    clear();
    let cfg = RouterConfig { fleet: 1, ..base_cfg() };
    let (router, addr) = start(cfg, InProcessLauncher::new(Duration::ZERO, 4));
    let gen = "GEN 4 0 0 0 -1 1 2";
    let (baseline, _) = run_to_single_terminal(addr, gen);
    assert_eq!(baseline.len(), 4);
    install(FaultPlan { seed: 9, corrupt_line: 1.0, ..FaultPlan::default() });
    let (toks, end) = run_to_single_terminal(addr, gen);
    assert!(toks.is_empty(), "corrupted request must stream no tokens, got {toks:?}");
    assert!(end.starts_with("ERR"), "one clean terminal, got {end}");
    clear();
    use std::sync::atomic::Ordering;
    // the worker rejected the line itself; nothing died, nothing failed over
    assert_eq!(router.stats.worker_lost.load(Ordering::Relaxed), 0);
    assert_eq!(router.stats.failovers.load(Ordering::Relaxed), 0);
    assert_eq!(router.fleet.healthy(), 1, "corruption must not cost capacity");
    let (toks, end) = run_to_single_terminal(addr, gen);
    assert_eq!(toks, baseline, "{end}");
    router.drain();
}
