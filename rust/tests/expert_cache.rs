//! Expert-residency cache integration:
//!
//! * bit-parity: cached vs synthesized `experts_forward` produce
//!   *identical* outputs across budgets and admission/eviction churn,
//! * the hard byte-budget invariant (resident bytes never exceed it),
//! * memmodel closed forms pinned against actual layer bytes at the
//!   paper shape (ButterflyMoE, StandardMoe, and the resident working
//!   set),
//! * the cached serving path end-to-end: identical token streams, cache
//!   gauge in metrics.

use std::sync::Arc;
use std::time::Duration;

use butterfly_moe::coordinator::{
    collect_stream, warm, Coordinator, GenerateRequest, NativeMoeBackend, SchedulerConfig,
};
use butterfly_moe::expertcache::{
    decoded_expert_bytes, CacheStatsSnapshot, DecodedExpert, ExpertCacheConfig,
};
use butterfly_moe::memmodel::{self, LayerShape, Method};
use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer, StandardMoeLayer};
use butterfly_moe::testutil;
use butterfly_moe::util::Rng;

const D: usize = 64;
const DFF: usize = 128;
const E: usize = 8;

fn layer(seed: u64) -> ButterflyMoeLayer {
    testutil::butterfly_layer(D, DFF, E, 2, seed)
}

/// Replace the gate with one-hot rows so tests can steer routing
/// deterministically: a token with `x[hot] = 4, x[warm] = 2` routes
/// top-2 to exactly `{hot, warm}`.
fn steer_gate(l: &mut ButterflyMoeLayer) {
    let (e, d) = (l.gate.w.shape[0], l.gate.w.shape[1]);
    l.gate.w.data.fill(0.0);
    for i in 0..e {
        l.gate.w.data[i * d + i] = 4.0;
    }
}

fn steering_token(hot: usize, warm2: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; D];
    x[hot] = 4.0;
    x[warm2] = 2.0;
    x
}

#[test]
fn cached_forward_bit_identical_across_budgets_and_churn() {
    let entry = decoded_expert_bytes(DFF, D);
    for budget_experts in [0usize, 1, 3, E] {
        let mut plain = layer(11);
        let mut cached = layer(11); // identical weights (same seed)
        steer_gate(&mut plain);
        steer_gate(&mut cached);
        let cache = cached.attach_expert_cache(ExpertCacheConfig {
            ewma_alpha: 0.5,
            min_resident_ticks: 1,
            max_admissions_per_tick: 4,
            ..ExpertCacheConfig::with_budget_bytes(budget_experts * entry)
        });
        let mut rng = Rng::new(99);
        for round in 0..40usize {
            // phase 1 keeps experts {1,2} hot, phase 2 shifts to {5,6}:
            // at small budgets this forces admission churn and
            // replacement evictions while parity must hold bit-for-bit
            let (hot, warm2) = if round < 20 { (1, 2) } else { (5, 6) };
            let t = 1 + round % 4;
            let mut x = steering_token(hot, warm2);
            for _ in 1..t {
                x.extend((0..D).map(|_| rng.normal_f32(1.0)));
            }
            let mut ha = vec![0.0f32; t * DFF];
            let mut hb = vec![0.0f32; t * DFF];
            let la = plain.experts_forward(&x, t, &mut ha);
            let lb = cached.experts_forward(&x, t, &mut hb);
            assert_eq!(ha, hb, "budget={budget_experts} round={round}");
            assert_eq!(la, lb, "loads must agree");
            cache.tick();
            let s = cache.snapshot();
            assert!(
                s.resident_bytes <= budget_experts * entry,
                "budget exceeded: {} > {}",
                s.resident_bytes,
                budget_experts * entry
            );
            assert_eq!(s.resident_bytes, s.resident_experts * entry);
        }
        let s = cache.snapshot();
        if budget_experts == 0 {
            assert!(!s.enabled);
            assert_eq!(s.hits + s.misses, 0, "disabled cache must record nothing");
            assert_eq!(s.resident_bytes, 0);
        } else {
            assert!(s.hits > 0, "budget {budget_experts}: no hits");
            assert!(s.materializations > 0);
        }
        if budget_experts == 1 {
            assert!(s.evictions > 0, "hot-set shift must churn a 1-expert budget");
        }
    }
}

#[test]
fn memmodel_closed_forms_pin_actual_layer_bytes() {
    let s = LayerShape::paper();
    let mut rng = Rng::new(3);
    // ButterflyMoE at the paper shape: Prop. 1 vs packed reality
    // (difference is only the substrate's byte-granularity ceil)
    let bf = ButterflyMoeLayer::random(512, 2048, 4, 2, None, &mut rng);
    let predicted = memmodel::butterfly_bytes(4, s);
    let actual = bf.expert_bytes() as f64;
    assert!((actual - predicted).abs() < 1.0, "{actual} vs {predicted}");
    // StandardMoe: exact
    let st = StandardMoeLayer::random(512, 2048, 2, 1, &mut rng);
    assert_eq!(st.expert_bytes() as f64, Method::StandardMoe.bytes(2, s));
    // resident working-set closed form == actually materialized bytes
    let dec = DecodedExpert::materialize(&bf.substrate);
    assert_eq!(dec.nbytes() as f64, memmodel::resident_expert_bytes(s));
    assert_eq!(dec.nbytes(), decoded_expert_bytes(2048, 512));
    // attaching a cache never changes expert-identity accounting
    let mut rng2 = Rng::new(3);
    let mut bf2 = ButterflyMoeLayer::random(512, 2048, 4, 2, None, &mut rng2);
    let before = bf2.expert_bytes();
    bf2.attach_expert_cache(ExpertCacheConfig::with_budget_mb(16.0));
    assert_eq!(bf2.expert_bytes(), before);
}

#[test]
fn fractional_budget_rounds_down_and_is_never_exceeded() {
    let entry = decoded_expert_bytes(DFF, D);
    let mut l = layer(21);
    let budget = entry * 5 / 2; // room for 2.5 experts -> 2 resident max
    let cache = l.attach_expert_cache(ExpertCacheConfig::with_budget_bytes(budget));
    assert_eq!(cache.capacity_experts(), 2);
    cache.prewarm();
    let s = cache.snapshot();
    assert_eq!(s.resident_experts, 2);
    assert!(s.resident_bytes <= cache.budget_bytes());
}

#[test]
fn cached_serving_sessions_match_uncached_bitwise() {
    let run = |cache_mb: f64| {
        let mut l = testutil::butterfly_layer(D, 256, E, 2, 7);
        let cache = (cache_mb > 0.0)
            .then(|| l.attach_expert_cache(ExpertCacheConfig::with_budget_mb(cache_mb)));
        let backend = Arc::new(NativeMoeBackend::new(Arc::new(l), 512, 32, 8));
        warm(backend.as_ref()).unwrap();
        let coord = Coordinator::start(backend, SchedulerConfig::new(8, Duration::from_millis(1)));
        let rxs: Vec<_> = (0..6)
            .map(|i| coord.submit(GenerateRequest::greedy(vec![(i * 31 % 512) as i32, 5, 9], 12)))
            .collect();
        let toks: Vec<Vec<i32>> = rxs
            .into_iter()
            .map(|rx| collect_stream(&rx, Duration::from_secs(30)).unwrap().tokens)
            .collect();
        let snap = coord.metrics.snapshot();
        coord.shutdown();
        let cache_snap: Option<CacheStatsSnapshot> = cache.map(|c| c.snapshot());
        (toks, snap, cache_snap)
    };
    let (toks_plain, snap_plain, no_cache) = run(0.0);
    assert!(no_cache.is_none());
    assert!(snap_plain.cache.is_none());
    // 8 MB budget holds every expert at this shape: all dispatches hit
    let (toks_cached, snap_cached, cache_snap) = run(8.0);
    assert_eq!(toks_plain, toks_cached, "cached serving must decode identical tokens");
    let gauge = snap_cached.cache.expect("engine loop must publish the cache gauge");
    assert!(gauge.enabled);
    let cs = cache_snap.unwrap();
    assert!(cs.hits > 0, "prewarmed cache must serve hits");
    assert_eq!(cs.resident_experts, E, "budget holds all experts");
    assert!(cs.resident_bytes <= cs.budget_bytes);
}
