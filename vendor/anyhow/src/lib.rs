//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros.  Semantics follow
//! upstream anyhow: `{}` prints the outermost message, `{:#}` prints
//! the whole context chain.

use std::fmt;

/// Boxed error with a chain of context frames (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Context frames, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts, capturing its source chain.  (Like upstream,
/// `Error` itself deliberately does not implement `std::error::Error`,
/// which is what makes this blanket impl coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors ( `Result` ) or missing values ( `Option` ).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or anything
/// `Display`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
