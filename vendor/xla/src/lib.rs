//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The build environment has no registry or native XLA/PJRT toolchain,
//! so this shim provides the exact API surface `runtime/` compiles
//! against.  Constructors succeed; anything that would require a real
//! PJRT runtime returns an "unavailable" error at *runtime*.  The
//! native serving path (`NativeMoeBackend`, scheduler, TCP frontend)
//! never touches this crate.  To execute compiled HLO artifacts,
//! replace this path dependency with the real `xla` crate.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA is stubbed in this offline build (vendor/xla); \
         link the real xla crate to execute HLO artifacts"
    ))
}

/// Host element types the workspace exchanges with literals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal handle.  Construction and reshape are cheap no-ops
/// here; reading values back requires a real runtime.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: ArrayShape,
}

impl Literal {
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal {
            shape: ArrayShape {
                dims: Vec::new(),
                ty: T::TY,
            },
        }
    }

    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            shape: ArrayShape {
                dims: vec![values.len() as i64],
                ty: T::TY,
            },
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal {
            shape: ArrayShape {
                dims: dims.to_vec(),
                ty: self.shape.ty,
            },
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_work_offline() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]).reshape(&[3, 1]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[3, 1]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(Literal::scalar(7i32).array_shape().unwrap().ty(), ElementType::S32);
    }

    #[test]
    fn runtime_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("stubbed"));
    }
}
