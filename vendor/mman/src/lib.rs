//! Minimal memory-mapping shim for the offline vendor set.
//!
//! The `libc` crate is not available offline, so the few POSIX calls the
//! model-artifact loader needs (`mmap`, `munmap`, `pread`) are declared
//! here as raw `extern "C"` bindings.  They resolve at link time against
//! the platform C library that `std` already links — no new dependency,
//! no registry access.  Only the read-only-file-mapping subset is
//! declared; everything else stays in `std`.
//!
//! Constants are the POSIX values shared by Linux and macOS (the two
//! targets the crate builds on); `off_t` is declared as `i64`, which is
//! correct on every 64-bit unix this repo targets.  The safe wrapper
//! (`butterfly_moe::artifact::mmapfile`) compiles the mapping path only
//! on `cfg(all(unix, target_pointer_width = "64"))` and falls back to a
//! heap read elsewhere, so a 32-bit or non-unix build never reaches
//! these declarations.

#[cfg(all(unix, target_pointer_width = "64"))]
pub mod sys {
    use core::ffi::c_void;

    /// Pages may be read.
    pub const PROT_READ: i32 = 1;
    /// Share the mapping (read-only here): concurrent processes mapping
    /// the same model file share its page-cache pages.
    pub const MAP_SHARED: i32 = 1;
    /// `mmap`'s error return.
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, length: usize) -> i32;
        pub fn pread(fd: i32, buf: *mut c_void, count: usize, offset: i64) -> isize;
    }
}
