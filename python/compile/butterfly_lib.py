"""Pure-jnp butterfly transform library (L2 building block).

A butterfly transform over ``d = 2^m`` dimensions is a product of ``m``
block-diagonal Givens-rotation stages.  At stage ``l`` (stride
``s = 2^l``) coordinates ``i`` and ``i + s`` are paired whenever bit ``l``
of ``i`` is zero, and each pair is rotated by a learned angle:

    [a']   [ cos t  -sin t ] [a]
    [b'] = [ sin t   cos t ] [b]

This stride-pairing formulation is the standard (FFT-style) equivalent of
the paper's "perfect shuffle + block-diagonal" product (eq. 3): the
shuffle only relabels which contiguous pair a coordinate lands in.

Angle layout — the single source of truth shared with the Rust engine
(rust/src/butterfly/) and the Pallas kernel (kernels/butterfly.py):

    angles: float32[depth, d/2]
    stage l, pair j  pairs coordinates (lo, hi) with
        s   = 2^l
        blk = j // s          # which 2s-sized block
        off = j % s           # offset inside the block
        lo  = blk * 2s + off
        hi  = lo + s

``depth <= m`` truncated stacks are allowed (Table 2 ablation); a
truncated stack is still orthogonal, just less expressive.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def num_stages(d: int) -> int:
    m = int(math.log2(d))
    assert 1 << m == d, f"d={d} is not a power of two"
    return m


def stage_apply(x: jnp.ndarray, ang: jnp.ndarray, stride: int, transpose: bool) -> jnp.ndarray:
    """Apply one Givens stage of stride ``stride`` to ``x[..., d]``.

    ``ang`` has shape ``(d/2,)`` laid out as documented above.  With
    ``transpose=True`` the inverse (= transpose) rotation is applied.
    """
    d = x.shape[-1]
    lead = x.shape[:-1]
    nblk = d // (2 * stride)
    # (..., nblk, 2, stride): axis -2 separates the (lo, hi) partners.
    xr = x.reshape(*lead, nblk, 2, stride)
    a = xr[..., 0, :]
    b = xr[..., 1, :]
    angr = ang.reshape(nblk, stride)
    c = jnp.cos(angr)
    s = jnp.sin(angr)
    if transpose:
        s = -s
    na = c * a - s * b
    nb = s * a + c * b
    out = jnp.stack([na, nb], axis=-2)
    return out.reshape(*lead, d)


def butterfly_apply(x: jnp.ndarray, angles: jnp.ndarray, transpose: bool = False) -> jnp.ndarray:
    """Apply the butterfly stack ``B`` (or ``B^T``) to ``x[..., d]``.

    ``angles``: float32[depth, d/2].  Forward order is stage 0 (stride 1)
    applied first, i.e. ``B = D_{m-1} ... D_1 D_0`` acting on column
    vectors; the transpose applies stages in reverse with negated angles.
    """
    depth = angles.shape[0]
    order = range(depth - 1, -1, -1) if transpose else range(depth)
    for l in order:
        x = stage_apply(x, angles[l], 1 << l, transpose)
    return x


def butterfly_matrix(angles: jnp.ndarray, d: int) -> jnp.ndarray:
    """Materialize ``B`` as a dense (d, d) matrix — tests/analysis only."""
    eye = jnp.eye(d, dtype=jnp.float32)
    # butterfly_apply treats the last axis as the vector; rows of eye are
    # basis vectors, so apply and transpose to get column-action matrix.
    return butterfly_apply(eye, angles).T


def init_angles(key, depth: int, d: int, std: float = 0.01) -> jnp.ndarray:
    """Near-identity random init, eq. (7): theta ~ N(0, 0.01^2)."""
    import jax

    return std * jax.random.normal(key, (depth, d // 2), dtype=jnp.float32)
