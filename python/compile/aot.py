"""AOT compiler: lower every L2 graph to HLO text + export params/manifest.

Interchange contract with the Rust runtime (rust/src/runtime):

- ``artifacts/<name>.hlo.txt`` — HLO **text** (NOT ``.serialize()``: the
  image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos; the
  text parser reassigns ids.  See /opt/xla-example/README.md).
- ``artifacts/manifest.json`` — for each artifact: input/output names,
  shapes, dtypes in the exact flattened order the executable expects.
- ``artifacts/<cfg>.params.bmoe`` — initial parameters in the BMOE binary
  tensor container (see python/compile/bmoe_io.py and
  rust/src/tensor/store.rs; both sides implement the same spec).

Run via ``make artifacts``.  Python never runs again after this.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import bmoe_io
from compile.configs import PRESETS, ModelConfig
from compile.model import init_params
from compile.train import (
    init_opt_state,
    make_eval,
    make_lm_logits,
    make_moe_layer_fwd,
    make_train_step,
)

# Batch-size buckets for serving artifacts; the Rust dynamic batcher pads
# each flush to the smallest bucket that fits (coordinator/batcher.rs).
LM_BATCH_BUCKETS = (1, 4, 16)
MOE_TOKEN_BUCKETS = (16, 64, 256)
TRAIN_BATCH = 16


def to_hlo_text(lowered) -> str:
    """jax lowered -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_names(tree, prefix: str) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(prefix + ".".join(parts))
    return names


def _specs(tree, prefix: str):
    flat, _ = jax.tree_util.tree_flatten(tree)
    names = _flat_names(tree, prefix)
    return [
        {"name": n, "shape": list(l.shape), "dtype": str(l.dtype)}
        for n, l in zip(names, flat)
    ]


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "configs": {}, "artifacts": [], "params": {}}
        os.makedirs(out_dir, exist_ok=True)

    def add_config(self, cfg: ModelConfig):
        self.manifest["configs"][cfg.name] = cfg.as_dict()

    def export_params(self, cfg: ModelConfig, seed: int = 0):
        params = init_params(cfg, seed)
        flat, _ = jax.tree_util.tree_flatten(params)
        names = _flat_names(params, "")
        fname = f"{cfg.name}.params.bmoe"
        bmoe_io.write_bmoe(
            os.path.join(self.out_dir, fname),
            [(n, jnp.asarray(l)) for n, l in zip(names, flat)],
        )
        self.manifest["params"][cfg.name] = {
            "file": fname,
            "seed": seed,
            "names": names,
            "tensors": _specs(params, ""),
        }
        return params

    def lower(self, name: str, kind: str, cfg: ModelConfig, fn, args_tree, in_specs):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args_tree)
        out_sh = jax.eval_shape(fn, *args_tree)
        flat_out, _ = jax.tree_util.tree_flatten(out_sh)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "kind": kind,
                "config": cfg.name,
                "inputs": in_specs,
                "outputs": [
                    {"shape": list(l.shape), "dtype": str(l.dtype)} for l in flat_out
                ],
            }
        )
        print(f"  lowered {name}: {len(text)/1e6:.2f} MB hlo, {time.time()-t0:.1f}s")

    def build_train_step(self, cfg: ModelConfig):
        params = init_params(cfg, 0)
        m, v = init_opt_state(params)
        step = jnp.int32(0)
        lr = jnp.float32(1e-3)
        toks = jnp.zeros((TRAIN_BATCH, cfg.seq_len), jnp.int32)
        args = (_abstract(params), _abstract(m), _abstract(v), step, lr, toks, toks)
        in_specs = (
            _specs(params, "params.")
            + _specs(m, "m.")
            + _specs(v, "v.")
            + [
                {"name": "step", "shape": [], "dtype": "int32"},
                {"name": "lr", "shape": [], "dtype": "float32"},
                {"name": "tokens", "shape": [TRAIN_BATCH, cfg.seq_len], "dtype": "int32"},
                {"name": "targets", "shape": [TRAIN_BATCH, cfg.seq_len], "dtype": "int32"},
            ]
        )
        self.lower(
            f"{cfg.name}__train_step", "train_step", cfg, make_train_step(cfg), args, in_specs
        )

    def build_eval(self, cfg: ModelConfig, batch: int = TRAIN_BATCH):
        params = _abstract(init_params(cfg, 0))
        toks = jnp.zeros((batch, cfg.seq_len), jnp.int32)
        in_specs = _specs(params, "params.") + [
            {"name": "tokens", "shape": [batch, cfg.seq_len], "dtype": "int32"},
            {"name": "targets", "shape": [batch, cfg.seq_len], "dtype": "int32"},
        ]
        self.lower(f"{cfg.name}__eval", "eval", cfg, make_eval(cfg), (params, toks, toks), in_specs)

    def build_lm_logits(self, cfg: ModelConfig, use_pallas: bool = False):
        params = _abstract(init_params(cfg, 0))
        for b in LM_BATCH_BUCKETS:
            toks = jnp.zeros((b, cfg.seq_len), jnp.int32)
            in_specs = _specs(params, "params.") + [
                {"name": "tokens", "shape": [b, cfg.seq_len], "dtype": "int32"}
            ]
            self.lower(
                f"{cfg.name}__lm_logits_b{b}",
                "lm_logits",
                cfg,
                make_lm_logits(cfg, use_pallas),
                (params, toks),
                in_specs,
            )

    def build_moe_fwd(self, cfg: ModelConfig, use_pallas: bool = True):
        from compile.model import init_ffn_params

        ffn = _abstract(init_ffn_params(cfg, jax.random.PRNGKey(0)))
        suffix = "" if use_pallas else "_jnp"
        for t in MOE_TOKEN_BUCKETS:
            x = jnp.zeros((t, cfg.d_model), jnp.float32)
            in_specs = _specs(ffn, "ffn.") + [
                {"name": "x", "shape": [t, cfg.d_model], "dtype": "float32"}
            ]
            self.lower(
                f"{cfg.name}__moe_fwd{suffix}_t{t}",
                "moe_fwd",
                cfg,
                make_moe_layer_fwd(cfg, use_pallas),
                (ffn, x),
                in_specs,
            )
            # export the ffn params for this layer too (parity tests)

    def export_ffn_params(self, cfg: ModelConfig, seed: int = 0):
        from compile.model import init_ffn_params

        ffn = init_ffn_params(cfg, jax.random.PRNGKey(seed))
        flat, _ = jax.tree_util.tree_flatten(ffn)
        names = _flat_names(ffn, "ffn.")
        fname = f"{cfg.name}.ffn.bmoe"
        bmoe_io.write_bmoe(
            os.path.join(self.out_dir, fname),
            [(n, jnp.asarray(l)) for n, l in zip(names, flat)],
        )
        self.manifest["params"][cfg.name + ".ffn"] = {
            "file": fname,
            "seed": seed,
            "names": names,
            "tensors": _specs(ffn, "ffn."),
        }

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--profile",
        default="full",
        choices=("ci", "full"),
        help="ci: tiny-only artifacts for fast tests; full: everything",
    )
    args = ap.parse_args()
    b = Builder(args.out)

    tiny = PRESETS["tiny"]
    for name in ("tiny", "tiny_static", "tiny_standard", "tiny_dense"):
        cfg = PRESETS[name]
        b.add_config(cfg)
        b.export_params(cfg, seed=0)
        b.build_train_step(cfg)
    b.build_eval(tiny)
    b.build_lm_logits(tiny)
    b.build_moe_fwd(tiny, use_pallas=True)
    b.export_ffn_params(tiny)

    if args.profile == "full":
        small = PRESETS["small"]
        b.add_config(small)
        b.export_params(small, seed=0)
        b.build_train_step(small)
        b.build_eval(small)
        b.build_lm_logits(small)

        paper = PRESETS["paper_layer"]
        b.add_config(paper)
        b.export_ffn_params(paper)
        b.build_moe_fwd(paper, use_pallas=True)

    b.finish()


if __name__ == "__main__":
    sys.exit(main())
