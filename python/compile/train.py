"""L2 training graph: loss, hand-rolled AdamW, and the jit-able train step.

optax is not available in this offline image, so AdamW is implemented
directly (decoupled weight decay, bias-corrected moments).  The whole
step — forward, backward, optimizer update — lowers into a single HLO
module; the Rust driver (rust/src/train) keeps params/moments as
device-resident PJRT buffers and feeds back the outputs of step t as the
inputs of step t+1, so training runs with zero Python and zero host
round-trips for the state.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from compile.configs import ModelConfig
from compile.model import Params, init_params, lm_loss

OptState = Dict[str, Any]

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01


def init_opt_state(params: Params) -> Tuple[Params, Params]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def _adamw_update(p, g, m, v, lr, bc1, bc2):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    new_p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + WEIGHT_DECAY * p)
    return new_p, m, v


def train_step(
    params: Params,
    m: Params,
    v: Params,
    step: jnp.ndarray,
    lr: jnp.ndarray,
    tokens: jnp.ndarray,
    targets: jnp.ndarray,
    cfg: ModelConfig,
):
    """One AdamW step.  Returns (params', m', v', step', loss, ce, bal, load).

    ``step`` is an int32 scalar (0-based count of completed steps); ``lr``
    an f32 scalar so the Rust driver owns the schedule.
    """
    (loss, (ce, bal, loads)), grads = jax.value_and_grad(
        lambda p: lm_loss(p, tokens, targets, cfg), has_aux=True
    )(params)

    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t

    flat_wp, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_p = [l for _, l in flat_wp]
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(m)
    flat_v = treedef.flatten_up_to(v)
    new_p, new_m, new_v = [], [], []
    for (path, p_), g_, m_, v_ in zip(flat_wp, flat_g, flat_m, flat_v):
        leaf = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if not cfg.learn_rotations and leaf in ("theta", "phi"):
            # Frozen rotations (Fig. 4 static baseline): no gradient AND
            # no weight decay — the parameters must not move at all.
            new_p.append(p_)
            new_m.append(m_)
            new_v.append(v_)
            continue
        np_, nm_, nv_ = _adamw_update(p_, g_, m_, v_, lr, bc1, bc2)
        new_p.append(np_)
        new_m.append(nm_)
        new_v.append(nv_)
    params = jax.tree_util.tree_unflatten(treedef, new_p)
    m = jax.tree_util.tree_unflatten(treedef, new_m)
    v = jax.tree_util.tree_unflatten(treedef, new_v)
    # Mean router load across blocks — the driver logs it per step.
    mean_load = jnp.mean(loads, axis=0)
    return params, m, v, step + 1, loss, ce, bal, mean_load


def make_train_step(cfg: ModelConfig):
    def fn(params, m, v, step, lr, tokens, targets):
        return train_step(params, m, v, step, lr, tokens, targets, cfg)

    return fn


def make_eval(cfg: ModelConfig, use_pallas: bool = False):
    """(params, tokens, targets) -> (ce_loss, last-position logits)."""

    def fn(params, tokens, targets):
        loss, (ce, bal, loads) = lm_loss(params, tokens, targets, cfg, use_pallas)
        return ce, loss

    return fn


def make_lm_logits(cfg: ModelConfig, use_pallas: bool = False):
    """(params, tokens) -> logits (B, L, V) — the serving forward."""
    from compile.model import lm_forward

    def fn(params, tokens):
        logits, _ = lm_forward(params, tokens, cfg, use_pallas)
        return logits

    return fn


def make_moe_layer_fwd(cfg: ModelConfig, use_pallas: bool = True):
    """(ffn_params, x (T, d_model)) -> y (T, d_model), single MoE layer.

    This is the serving hot-path artifact: the deployed graph really does
    run the L1 Pallas kernels (interpret-lowered).
    """
    from compile.model import moe_ffn_forward

    def fn(ffn_params, x):
        y, load = moe_ffn_forward(x[None], ffn_params, cfg, use_pallas)
        return y[0], load

    return fn


def smoke_train(cfg: ModelConfig, steps: int = 3, seed: int = 0):
    """Tiny pure-python training run used by pytest to sanity-check descent."""
    params = init_params(cfg, seed)
    m, v = init_opt_state(params)
    step = jnp.int32(0)
    key = jax.random.PRNGKey(42)
    fn = jax.jit(make_train_step(cfg))
    losses = []
    for i in range(steps):
        key, k1 = jax.random.split(key)
        toks = jax.random.randint(k1, (4, cfg.seq_len), 0, cfg.vocab)
        targets = jnp.roll(toks, -1, axis=1)
        params, m, v, step, loss, ce, bal, load = fn(
            params, m, v, step, jnp.float32(1e-3), toks, targets
        )
        losses.append(float(loss))
    return losses
