"""Ternary (1.58-bit) quantization with AbsMean scaling and STE.

Follows BitNet b1.58 (Ma et al., 2024), eq. (5) of the paper:

    gamma = mean(|W|)
    Q(W)  = gamma * clip(round(W / gamma), -1, +1)

The Straight-Through Estimator treats dQ/dW = I so the latent
full-precision ``W`` keeps receiving gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-8


def absmean_scale(w: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor AbsMean scale gamma (scalar array)."""
    return jnp.mean(jnp.abs(w)) + EPS


def ternary_quantize(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return (q, gamma) with q in {-1, 0, +1} (float32) and scalar gamma."""
    gamma = absmean_scale(w)
    q = jnp.clip(jnp.round(w / gamma), -1.0, 1.0)
    return q, gamma


def quantize_ste(w: jnp.ndarray) -> jnp.ndarray:
    """Quantize-dequantize with straight-through gradients.

    Forward value is ``gamma * q``; backward is identity on ``w``.
    """
    q, gamma = ternary_quantize(w)
    wq = gamma * q
    return w + jax.lax.stop_gradient(wq - w)


def quant_error(w: jnp.ndarray) -> jnp.ndarray:
    """Relative weight quantization MSE: ||Q(W)-W||^2 / ||W||^2."""
    q, gamma = ternary_quantize(w)
    err = gamma * q - w
    return jnp.sum(err * err) / (jnp.sum(w * w) + EPS)


def activation_quant_error(y_q: jnp.ndarray, y_fp: jnp.ndarray) -> jnp.ndarray:
    """Relative output error between quantized and full-precision paths.

    This is the Fig. 4 metric: how much the ternarized substrate perturbs
    the expert output (percentages in the paper are 100x this value).
    """
    num = jnp.sum((y_q - y_fp) ** 2)
    den = jnp.sum(y_fp**2) + EPS
    return num / den
