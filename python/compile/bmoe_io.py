"""BMOE binary tensor container — python writer/reader.

Spec (little-endian throughout; mirrored by rust/src/tensor/store.rs):

    magic   : 6 bytes  b"BMOE1\\0"
    count   : u32      number of tensors
    per tensor:
        name_len : u16
        name     : name_len bytes (utf-8)
        dtype    : u8   (0 = f32, 1 = i32, 2 = u8)
        ndim     : u8
        dims     : ndim x u32
        data     : prod(dims) * itemsize bytes, row-major

Used for initial params (aot.py), checkpoints (rust train driver), and
test vectors.  This layout block is normative and mirrored verbatim in
DESIGN.md §3, which also specifies the model-artifact schema layered on
top (the ``__model__`` JSON manifest + ``layers.{l}.*`` tensors that
``bmoe pack-model`` writes and the mmap loader reads).  The exact bytes
are pinned cross-language by test_cross_language.py::test_golden_bytes_exact
and rust/src/tensor/store.rs::golden_bytes_exact.

Note: ``np.ascontiguousarray`` promotes 0-d arrays to 1-d, so this
writer stores scalars as shape ``(1,)``; readers on both sides accept
rank-0 and ``(1,)`` interchangeably.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"BMOE1\x00"
DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def write_bmoe(path: str, tensors: list[tuple[str, "np.ndarray"]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            a = np.ascontiguousarray(arr)
            if a.dtype == np.int64:
                a = a.astype(np.int32)
            if a.dtype not in DTYPE_CODES:
                a = a.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPE_CODES[a.dtype], a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            f.write(a.tobytes())


def read_bmoe(path: str) -> list[tuple[str, "np.ndarray"]]:
    out = []
    with open(path, "rb") as f:
        assert f.read(6) == MAGIC, f"{path}: bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(DTYPES[code])
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dt.itemsize), dtype=dt).reshape(dims)
            out.append((name, data))
    return out
