"""L2: the ButterflyMoE transformer LM in JAX (build-time only).

Architecture notes
------------------
The paper treats an expert as a *single* matrix ``W_i = B(phi_i) Q(W_base)
B(theta_i)^T`` mapping d_model -> d_ff (Alg. 1 outputs live in R^{d_ff}).
To obtain a working residual FFN we follow that literally for the expert
(up) path and close the block with a *shared* ternary down-projection:

    h   = sum_{i in topk} g_i * OrbitExpert_i(x)        # (.., d_ff)
    y   = gelu(h) @ Q(W_down)^T                          # (.., d_model)

Per-expert storage is then exactly the two butterflies of Prop. 1 (one
over d_model, one over d_ff); both substrates are ternary and sit in the
O(d^2) term.  The "standard" baseline stores a dense f32 ``W_i`` per
expert with the same shared down projection, so the memory comparison is
apples-to-apples (64 experts, d=512, d_ff=2048 -> 256 MB of expert
weights, the paper's Table 1 row).

Routing is the dense-mask formulation (every expert computed, weights
zero outside the top-k): shapes stay static, which AOT lowering requires;
the Rust native engine implements the sparse gather/scatter dispatch of
Alg. 1 and is parity-tested against this graph.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from compile import butterfly_lib as bl
from compile.configs import ModelConfig
from compile.kernels import ref as kref
from compile.quant import quantize_ste

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _dense_init(key, shape, scale=None):
    fan_in = shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_ffn_params(cfg: ModelConfig, key) -> Params:
    """FFN parameters for one block, per cfg.arch."""
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 8)
    depth_in = cfg.bfly_depth or bl.num_stages(d)
    depth_out = cfg.bfly_depth or bl.num_stages(dff)
    if cfg.arch == "butterfly":
        theta = jnp.stack(
            [bl.init_angles(k, depth_in, d) for k in jax.random.split(keys[1], e)]
        )
        phi = jnp.stack(
            [bl.init_angles(k, depth_out, dff) for k in jax.random.split(keys[2], e)]
        )
        return {
            "gate": _dense_init(keys[0], (e, d)),
            "w_base": _dense_init(keys[3], (dff, d)),
            "theta": theta,
            "phi": phi,
            "w_down": _dense_init(keys[4], (d, dff)),
        }
    if cfg.arch == "standard":
        return {
            "gate": _dense_init(keys[0], (e, d)),
            "w_up": jnp.stack(
                [_dense_init(k, (dff, d)) for k in jax.random.split(keys[3], e)]
            ),
            "w_down": _dense_init(keys[4], (d, dff)),
        }
    # dense: single FFN, no routing
    return {
        "w_up": _dense_init(keys[3], (dff, d)),
        "w_down": _dense_init(keys[4], (d, dff)),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    key = jax.random.PRNGKey(seed)
    kt, kp, kb, kf = jax.random.split(key, 4)
    d = cfg.d_model
    blocks = []
    for bk in jax.random.split(kb, cfg.n_blocks):
        k1, k2, k3, k4, k5, kffn = jax.random.split(bk, 6)
        blocks.append(
            {
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "attn": {
                    "wq": _dense_init(k1, (d, d)),
                    "wk": _dense_init(k2, (d, d)),
                    "wv": _dense_init(k3, (d, d)),
                    "wo": _dense_init(k4, (d, d)),
                },
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "ffn": init_ffn_params(cfg, kffn),
            }
        )
    return {
        "embed": {
            "tok": _dense_init(kt, (cfg.vocab, d), scale=0.02),
            "pos": _dense_init(kp, (cfg.seq_len, d), scale=0.02),
        },
        "blocks": blocks,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
    }


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def layer_norm(x, p):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * p["g"] + p["b"]


def causal_attention(x, p, n_heads: int):
    b, l, d = x.shape
    hd = d // n_heads

    def split(w):
        return (x @ w.T).reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(p["wq"]), split(p["wk"]), split(p["wv"])
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, l, d)
    return y @ p["wo"].T


def _topk_by_argmax(probs: jnp.ndarray, k: int):
    """Top-k as k iterated argmaxes.

    ``jax.lax.top_k`` lowers to the HLO ``topk`` instruction, which the
    xla_extension 0.5.1 text parser used by the Rust runtime rejects
    ("unexpected attribute largest").  Iterated argmax lowers to plain
    reduce/select ops and is cheap for the small k (<= 2) we route with.
    Ties are broken by lowest index, matching lax.top_k.
    """
    e = probs.shape[-1]
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        onehot = jax.nn.one_hot(i, e, dtype=probs.dtype)
        v = jnp.sum(p * onehot, axis=-1)
        vals.append(v)
        idxs.append(i)
        p = p * (1.0 - onehot)  # mask the winner out
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def topk_gate(logits: jnp.ndarray, k: int):
    """Dense-mask top-k routing.

    logits: (T, E).  Returns (weights (T, E) summing to 1 with at most k
    non-zeros per row, load (E,) fraction of routed slots per expert).
    """
    t, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = _topk_by_argmax(probs, k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(idx, e, dtype=logits.dtype)  # (T, k, E)
    weights = jnp.einsum("tk,tke->te", vals, onehot)
    load = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / k  # sums to 1
    return weights, load


def orbit_expert_forward(x2d, theta, q, gamma, phi, use_pallas: bool):
    """Eq. (2) for one expert over flat tokens (T, d_model) -> (T, d_ff)."""
    if use_pallas:
        from compile.kernels.ternary import orbit_expert_pallas

        return orbit_expert_pallas(x2d, theta, q, gamma, phi)
    return kref.orbit_expert_ref(x2d, theta, q, gamma, phi)


def moe_ffn_forward(x, p, cfg: ModelConfig, use_pallas: bool = False):
    """MoE FFN over (B, L, d_model).  Returns (y, load)."""
    b, l, d = x.shape
    x2 = x.reshape(b * l, d)
    if cfg.arch == "dense":
        h = x2 @ p["w_up"].T
        y = jax.nn.gelu(h) @ p["w_down"].T
        load = jnp.ones((1,), dtype=x.dtype)
        return y.reshape(b, l, d), load

    logits = x2 @ p["gate"].T
    weights, load = topk_gate(logits, cfg.top_k)

    if cfg.arch == "butterfly":
        if use_pallas:
            # Serving path: the Pallas kernel takes the raw {-1,0,+1}
            # plane (cast to int8 in VMEM) and a separate gamma — the
            # same storage contract as the Rust native engine.
            from compile.quant import ternary_quantize

            wq, gamma = ternary_quantize(p["w_base"])
        else:
            # Training path: gamma folded in, STE gradients flow to the
            # latent full-precision substrate.
            wq = quantize_ste(p["w_base"])
            gamma = jnp.float32(1.0)
        theta = p["theta"]
        phi = p["phi"]
        if not cfg.learn_rotations:
            theta = jax.lax.stop_gradient(theta)
            phi = jax.lax.stop_gradient(phi)
        h = jnp.zeros((b * l, cfg.d_ff), dtype=x.dtype)
        for i in range(cfg.n_experts):
            yi = orbit_expert_forward(x2, theta[i], wq, gamma, phi[i], use_pallas)
            h = h + weights[:, i : i + 1] * yi
    else:  # standard
        h = jnp.zeros((b * l, cfg.d_ff), dtype=x.dtype)
        for i in range(cfg.n_experts):
            yi = x2 @ p["w_up"][i].T
            h = h + weights[:, i : i + 1] * yi

    y = jax.nn.gelu(h) @ p["w_down"].T
    return y.reshape(b, l, d), load


def lm_forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, use_pallas: bool = False):
    """Token ids (B, L) -> (logits (B, L, V), loads (n_blocks, E))."""
    b, l = tokens.shape
    x = params["embed"]["tok"][tokens] + params["embed"]["pos"][None, :l, :]
    loads = []
    for blk in params["blocks"]:
        x = x + causal_attention(layer_norm(x, blk["ln1"]), blk["attn"], cfg.n_heads)
        y, load = moe_ffn_forward(layer_norm(x, blk["ln2"]), blk["ffn"], cfg, use_pallas)
        x = x + y
        loads.append(load)
    x = layer_norm(x, params["ln_f"])
    logits = x @ params["embed"]["tok"].T  # tied embedding
    return logits, jnp.stack(loads)


def cross_entropy(logits, targets):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def balance_loss(loads: jnp.ndarray, cfg: ModelConfig):
    """Eq. (6): sum_i (n_i/(k*N) - 1/E)^2, summed over blocks."""
    if cfg.arch == "dense":
        return jnp.float32(0.0)
    target = 1.0 / cfg.n_experts
    return jnp.sum((loads - target) ** 2)


def lm_loss(params, tokens, targets, cfg: ModelConfig, use_pallas: bool = False):
    logits, loads = lm_forward(params, tokens, cfg, use_pallas)
    ce = cross_entropy(logits, targets)
    bal = balance_loss(loads, cfg)
    return ce + cfg.balance_lambda * bal, (ce, bal, loads)
