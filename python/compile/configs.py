"""Model / experiment configurations shared by the L2 model and aot.py.

Every dimension that a butterfly transform touches must be a power of two
(the paper assumes d = 2^m); ``ModelConfig.validate`` enforces this.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Configuration of the tiny transformer LM with ButterflyMoE FFNs."""

    name: str
    vocab: int = 512
    d_model: int = 64
    d_ff: int = 256
    n_heads: int = 4
    n_blocks: int = 2
    n_experts: int = 4
    top_k: int = 2
    seq_len: int = 32
    # Butterfly depth: number of Givens stages per transform.  None means
    # the full log2(d) stack.  Table 2 ablates {2, 4, 6, 9}.
    bfly_depth: Optional[int] = None
    # Expert parameterization: "butterfly" (the paper), "standard"
    # (independent dense experts) or "dense" (single FFN, no MoE).
    arch: str = "butterfly"
    # When False the rotation angles are frozen at their init values —
    # the "static rotation" baseline of Fig. 4.
    learn_rotations: bool = True
    # Load-balance loss weight (Switch-Transformer style), eq. (6).
    balance_lambda: float = 0.01
    dropout: float = 0.0  # no dropout: deterministic AOT graphs

    def validate(self) -> "ModelConfig":
        assert _is_pow2(self.d_model), f"d_model={self.d_model} not 2^m"
        assert _is_pow2(self.d_ff), f"d_ff={self.d_ff} not 2^m"
        assert self.d_model % self.n_heads == 0
        assert 1 <= self.top_k <= self.n_experts
        assert self.arch in ("butterfly", "standard", "dense")
        if self.bfly_depth is not None:
            import math

            assert 1 <= self.bfly_depth <= int(math.log2(self.d_model))
        return self

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# Presets.  "tiny" drives the test suite and the Fig. 4/5 training runs;
# "small" is the end-to-end LM example; "paper" matches the paper's layer
# shape (d=512, d_ff=2048, 8 experts) and is used for single-layer serving
# artifacts and parity benches (full-LM training at this size is out of
# scope for a CPU testbed).
TINY = ModelConfig(name="tiny").validate()
TINY_STATIC = dataclasses.replace(
    TINY, name="tiny_static", learn_rotations=False
).validate()
TINY_STANDARD = dataclasses.replace(TINY, name="tiny_standard", arch="standard").validate()
TINY_DENSE = dataclasses.replace(TINY, name="tiny_dense", arch="dense").validate()

SMALL = ModelConfig(
    name="small",
    vocab=4096,
    d_model=256,
    d_ff=1024,
    n_heads=8,
    n_blocks=4,
    n_experts=8,
    top_k=2,
    seq_len=64,
).validate()

PAPER_LAYER = ModelConfig(
    name="paper_layer",
    vocab=256,  # unused by the single-layer artifact
    d_model=512,
    d_ff=2048,
    n_heads=8,
    n_blocks=1,
    n_experts=8,
    top_k=2,
    seq_len=16,
).validate()

PRESETS = {
    c.name: c
    for c in (TINY, TINY_STATIC, TINY_STANDARD, TINY_DENSE, SMALL, PAPER_LAYER)
}
