"""L1 Pallas kernel: fused multi-stage butterfly transform.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel keeps a
``(block_rows, d)`` activation tile resident in VMEM and applies *all*
``depth`` Givens stages to it before writing back — one HBM round-trip for
the whole butterfly stack instead of one per stage (the GPU formulation of
Dao et al. does one strided global pass per stage).  The angle table
``(depth, d/2)`` is tiny (<= 4.5 KB at d=512 fp32) and is mapped whole
into VMEM for every grid step.

VMEM budget per grid step: block_rows*d*4 B for the tile plus the angle
table; at the default block_rows=128, d=512 that is 256 KB + 4.5 KB, far
under the ~16 MB VMEM of a TPU core, leaving room for double-buffering.

Lowered with ``interpret=True`` everywhere in this repo: the CPU PJRT
runtime cannot execute Mosaic custom-calls, and interpret mode lowers to
plain HLO that both pytest and the Rust runtime can run.  The kernel
*structure* (tiling, stage fusion) is the TPU contribution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def apply_stages(x: jnp.ndarray, ang: jnp.ndarray, depth: int, transpose: bool) -> jnp.ndarray:
    """Apply ``depth`` Givens stages to a resident (rows, d) tile.

    Pure value->value helper shared by the standalone butterfly kernel and
    the fused orbit-expert kernel; mirrors butterfly_lib.stage_apply
    exactly (same angle layout, same stage order).
    """
    rows, d = x.shape
    order = range(depth - 1, -1, -1) if transpose else range(depth)
    for l in order:
        stride = 1 << l
        nblk = d // (2 * stride)
        xr = x.reshape(rows, nblk, 2, stride)
        a = xr[:, :, 0, :]
        b = xr[:, :, 1, :]
        angl = ang[l, :].reshape(nblk, stride)
        c = jnp.cos(angl)
        s = jnp.sin(angl)
        if transpose:
            s = -s
        na = c * a - s * b
        nb = s * a + c * b
        x = jnp.stack([na, nb], axis=2).reshape(rows, d)
    return x


def _butterfly_kernel(x_ref, ang_ref, o_ref, *, depth: int, transpose: bool):
    """Pallas body: load tile, run all stages in VMEM, store once."""
    o_ref[...] = apply_stages(x_ref[...], ang_ref[...], depth, transpose)


@functools.partial(jax.jit, static_argnames=("transpose", "block_rows"))
def butterfly_apply_pallas(
    x: jnp.ndarray,
    angles: jnp.ndarray,
    transpose: bool = False,
    block_rows: int = 128,
) -> jnp.ndarray:
    """Fused butterfly transform of ``x`` (R, d) by ``angles`` (depth, d/2).

    Matches kernels.ref.butterfly_ref bit-for-bit up to float assoc.
    R must be divisible by the row block (callers pad); d a power of two.
    """
    rows, d = x.shape
    depth = angles.shape[0]
    br = min(block_rows, rows)
    if rows % br != 0:
        # Fall back to one tile per row-remainder-free chunking: pad.
        pad = br - rows % br
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        out = butterfly_apply_pallas(xp, angles, transpose=transpose, block_rows=br)
        return out[:rows]
    grid = (rows // br,)
    return pl.pallas_call(
        functools.partial(_butterfly_kernel, depth=depth, transpose=transpose),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((depth, d // 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, angles)
