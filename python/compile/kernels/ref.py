"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has an exact counterpart here; pytest
(python/tests/) sweeps shapes/dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))``.  The Rust native engine
(rust/src/butterfly, rust/src/ternary) is additionally tested against
vectors produced by these functions.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.butterfly_lib import butterfly_apply


def butterfly_ref(x: jnp.ndarray, angles: jnp.ndarray, transpose: bool = False) -> jnp.ndarray:
    """Oracle for kernels.butterfly.butterfly_apply_pallas."""
    return butterfly_apply(x, angles, transpose=transpose)


def ternary_matmul_ref(x: jnp.ndarray, q: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.ternary.ternary_matmul_pallas.

    x: (R, K) float32; q: (N, K) in {-1,0,+1}; gamma: scalar.
    Returns (R, N) = gamma * x @ q^T.
    """
    return (x @ q.astype(jnp.float32).T) * gamma


def orbit_expert_ref(
    x: jnp.ndarray,
    theta: jnp.ndarray,
    q: jnp.ndarray,
    gamma: jnp.ndarray,
    phi: jnp.ndarray,
) -> jnp.ndarray:
    """Oracle for the fused orbit-expert kernel (eq. 2):

        y = B(phi) ( Q(W_base) ( B(theta)^T x ) )

    x: (R, d_model); theta: (depth_in, d_model/2); q: (d_ff, d_model);
    phi: (depth_out, d_ff/2).  Returns (R, d_ff).
    """
    xr = butterfly_apply(x, theta, transpose=True)
    h = ternary_matmul_ref(xr, q, gamma)
    return butterfly_apply(h, phi, transpose=False)
