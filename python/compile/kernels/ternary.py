"""L1 Pallas kernels: ternary matmul and the fused orbit-expert pass.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's GPU story is an
add/sub-only GEMV over {-1,0,+1} weights.  The TPU has no ternary ALU
path — the correct translation is *memory-side*: the substrate is stored
ternary (1.58-bit in DRAM/HBM; int8 inside this build-time graph), widened
to the MXU's native dtype inside VMEM right before the systolic matmul.
The energy/bandwidth win is in HBM traffic, not multiplier width, and the
BlockSpec below expresses exactly that HBM->VMEM schedule:

    grid (R/bm, d_ff/bn); per step an (bm, K) activation tile and a
    (bn, K) weight tile stream into VMEM, one (bm, bn) f32 tile streams
    out.  K (= d_model) is kept whole per tile: at d_model=512, bm=bn=128
    this is 128*512*(4+1) B ~ 320 KB of VMEM per step.

``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.butterfly import apply_stages


def _ternary_matmul_kernel(x_ref, q_ref, g_ref, o_ref):
    """o = gamma * x @ q^T for one (bm, bn) output tile.

    x_ref (bm, K) f32; q_ref (bn, K) int8 in {-1,0,1}; g_ref (1, 1) f32.
    The int8->f32 widen happens in VMEM; on real TPU this would be a
    bf16 widen feeding the MXU.
    """
    x = x_ref[...]
    w = q_ref[...].astype(jnp.float32)
    gamma = g_ref[0, 0]
    o_ref[...] = jnp.dot(x, w.T) * gamma


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def ternary_matmul_pallas(
    x: jnp.ndarray,
    q: jnp.ndarray,
    gamma: jnp.ndarray,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """gamma * x @ q^T with q int8 ternary.  x (R, K), q (N, K) -> (R, N)."""
    rows, k = x.shape
    n, k2 = q.shape
    assert k == k2, (x.shape, q.shape)
    bm = min(block_m, rows)
    bn = min(block_n, n)
    if rows % bm != 0:
        pad = bm - rows % bm
        out = ternary_matmul_pallas(
            jnp.pad(x, ((0, pad), (0, 0))), q, gamma, block_m=bm, block_n=bn
        )
        return out[:rows]
    assert n % bn == 0, f"d_ff={n} not divisible by block_n={bn}"
    g2 = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (rows // bm, n // bn)
    return pl.pallas_call(
        _ternary_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=True,
    )(x, q.astype(jnp.int8), g2)


def _orbit_expert_kernel(x_ref, th_ref, q_ref, g_ref, ph_ref, o_ref, *, depth_in, depth_out):
    """Fused eq. (2) for one row tile: B(phi) (Q(W) (B(theta)^T x)).

    Fusing all three ops keeps the intermediate (bm, d_model) and
    (bm, d_ff) activations in VMEM — the expert is synthesized on the fly
    and never materialized, the paper's core inference property.
    """
    # Stage 1: input rotation B(theta)^T — shared butterfly stage math.
    xr = apply_stages(x_ref[...], th_ref[...], depth_in, transpose=True)
    # Stage 2: ternary substrate matmul (int8 widened in VMEM).
    w = q_ref[...].astype(jnp.float32)
    h = jnp.dot(xr, w.T) * g_ref[0, 0]
    # Stage 3: output rotation B(phi).
    o_ref[...] = apply_stages(h, ph_ref[...], depth_out, transpose=False)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def orbit_expert_pallas(
    x: jnp.ndarray,
    theta: jnp.ndarray,
    q: jnp.ndarray,
    gamma: jnp.ndarray,
    phi: jnp.ndarray,
    block_rows: int = 64,
) -> jnp.ndarray:
    """Fused orbit-expert forward.  x (R, d_model) -> (R, d_ff)."""
    rows, d_model = x.shape
    d_ff = q.shape[0]
    depth_in = theta.shape[0]
    depth_out = phi.shape[0]
    br = min(block_rows, rows)
    if rows % br != 0:
        pad = br - rows % br
        out = orbit_expert_pallas(
            jnp.pad(x, ((0, pad), (0, 0))), theta, q, gamma, phi, block_rows=br
        )
        return out[:rows]
    g2 = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (rows // br,)
    return pl.pallas_call(
        functools.partial(_orbit_expert_kernel, depth_in=depth_in, depth_out=depth_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d_model), lambda i: (i, 0)),
            pl.BlockSpec((depth_in, d_model // 2), lambda i: (0, 0)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((depth_out, d_ff // 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d_ff), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d_ff), jnp.float32),
        interpret=True,
    )(x, theta, q.astype(jnp.int8), g2, phi)
