# pytest: kernel vs ref allclose — the CORE correctness signal.
import pytest
