"""Ternary quantization + L1 ternary/orbit kernels vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.butterfly_lib import init_angles, num_stages
from compile.kernels.ref import orbit_expert_ref, ternary_matmul_ref
from compile.kernels.ternary import orbit_expert_pallas, ternary_matmul_pallas
from compile.quant import (
    activation_quant_error,
    absmean_scale,
    quant_error,
    quantize_ste,
    ternary_quantize,
)


def rand(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


class TestQuantization:
    def test_values_are_ternary(self):
        w = rand(0, (64, 32))
        q, gamma = ternary_quantize(w)
        assert set(np.unique(np.asarray(q))) <= {-1.0, 0.0, 1.0}
        assert float(gamma) > 0

    def test_absmean_scale(self):
        w = jnp.array([[1.0, -3.0], [0.0, 4.0]])
        assert np.isclose(float(absmean_scale(w)), 2.0, atol=1e-6)

    def test_ste_forward_value(self):
        w = rand(1, (16, 16))
        q, gamma = ternary_quantize(w)
        np.testing.assert_allclose(
            np.asarray(quantize_ste(w)), np.asarray(gamma * q), rtol=1e-6
        )

    def test_ste_gradient_is_identity(self):
        w = rand(2, (8, 8))
        g = jax.grad(lambda w: jnp.sum(quantize_ste(w) ** 2))(w)
        # d/dw sum(wq^2) with STE = 2*wq (identity through Q)
        q, gamma = ternary_quantize(w)
        np.testing.assert_allclose(np.asarray(g), np.asarray(2 * gamma * q), rtol=1e-5)

    def test_quant_error_zero_for_exact_ternary(self):
        # A tensor already of the form gamma*{-1,0,1} with mean|w|=gamma
        # quantizes exactly.
        w = 0.5 * jnp.array([[1.0, -1.0], [1.0, -1.0]])
        assert float(quant_error(w)) < 1e-10

    def test_quant_error_large_for_outliers(self):
        # A spread-out distribution has substantial relative error —
        # this is the "untrained" side of Fig. 4.
        w = rand(3, (64, 64), scale=1.0) ** 3  # heavy tails
        assert float(quant_error(w)) > 0.05

    def test_activation_quant_error_metric(self):
        y = rand(4, (8, 8))
        assert float(activation_quant_error(y, y)) == 0.0
        assert float(activation_quant_error(1.1 * y, y)) == pytest.approx(0.01, rel=1e-3)


@settings(deadline=None, max_examples=20)
@given(
    rows=st.integers(min_value=1, max_value=50),
    logk=st.integers(min_value=1, max_value=7),
    logn=st.integers(min_value=3, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ternary_matmul_pallas_matches_ref(rows, logk, logn, seed):
    k, n = 1 << logk, 1 << logn
    x = rand(seed, (rows, k))
    q = jax.random.randint(jax.random.PRNGKey(seed + 1), (n, k), -1, 2).astype(
        jnp.float32
    )
    gamma = jnp.float32(0.123)
    got = ternary_matmul_pallas(x, q, gamma, block_m=16, block_n=min(n, 64))
    want = ternary_matmul_ref(x, q, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(
    rows=st.integers(min_value=1, max_value=40),
    logd=st.integers(min_value=2, max_value=6),
    ff_mult=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_orbit_expert_pallas_matches_ref(rows, logd, ff_mult, seed):
    d = 1 << logd
    dff = d * ff_mult
    theta = init_angles(jax.random.PRNGKey(seed), num_stages(d), d, std=0.6)
    phi = init_angles(jax.random.PRNGKey(seed + 1), num_stages(dff), dff, std=0.6)
    q = jax.random.randint(jax.random.PRNGKey(seed + 2), (dff, d), -1, 2).astype(
        jnp.float32
    )
    x = rand(seed + 3, (rows, d))
    gamma = jnp.float32(0.5)
    got = orbit_expert_pallas(x, theta, q, gamma, phi, block_rows=16)
    want = orbit_expert_ref(x, theta, q, gamma, phi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_rotation_reduces_quant_error_for_outlier_basis():
    """The mechanism of §3.6.2: a rotation can move an outlier-heavy
    vector into a basis where ternary quantization hurts less.  We verify
    the *existence* direction: identity rotation error >= best butterfly
    rotation error found by a tiny gradient search."""
    d = 16
    key = jax.random.PRNGKey(0)
    # outlier activation: one huge channel
    x = jnp.ones((32, d)) * 0.1
    x = x.at[:, 3].set(8.0)
    w = jax.random.normal(key, (d, d)) * 0.5

    def err(ang):
        from compile.butterfly_lib import butterfly_apply

        xr = butterfly_apply(x, ang, transpose=True)
        q, gamma = ternary_quantize(w)
        y_q = xr @ (gamma * q).T
        y_fp = xr @ w.T
        return activation_quant_error(y_q, y_fp)

    ang0 = jnp.zeros((num_stages(d), d // 2))
    e0 = float(err(ang0))
    ang = ang0
    g = jax.jit(jax.grad(err))
    for _ in range(60):
        ang = ang - 0.1 * g(ang)
    e1 = float(err(ang))
    assert e1 < e0, (e0, e1)
