"""L1 butterfly kernel: hypothesis sweeps vs the pure-jnp oracle plus
algebraic invariants (orthogonality, inverse, depth truncation)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.butterfly_lib import (
    butterfly_apply,
    butterfly_matrix,
    init_angles,
    num_stages,
)
from compile.kernels.butterfly import butterfly_apply_pallas
from compile.kernels.ref import butterfly_ref


def rand_angles(seed, depth, d, std=0.7):
    return init_angles(jax.random.PRNGKey(seed), depth, d, std=std)


def rand_x(seed, rows, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (rows, d), dtype=jnp.float32)


@pytest.mark.parametrize("d", [2, 4, 8, 32, 128, 512])
def test_orthogonality(d):
    ang = rand_angles(0, num_stages(d), d)
    b = np.asarray(butterfly_matrix(ang, d))
    np.testing.assert_allclose(b @ b.T, np.eye(d), atol=1e-4)


@pytest.mark.parametrize("d", [4, 16, 64])
@pytest.mark.parametrize("depth", [1, 2, None])
def test_transpose_is_inverse(d, depth):
    depth = depth or num_stages(d)
    ang = rand_angles(1, depth, d)
    x = rand_x(2, 9, d)
    y = butterfly_apply(x, ang)
    np.testing.assert_allclose(
        np.asarray(butterfly_apply(y, ang, transpose=True)), np.asarray(x), atol=1e-5
    )


@pytest.mark.parametrize("d", [4, 16, 64])
def test_norm_preservation(d):
    """Orthogonal transforms preserve L2 norms (outlier-suppression
    without information loss — §3.6.2's argument depends on this)."""
    ang = rand_angles(3, num_stages(d), d)
    x = rand_x(4, 17, d)
    y = butterfly_apply(x, ang)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_zero_angles_is_identity():
    d = 32
    ang = jnp.zeros((num_stages(d), d // 2))
    x = rand_x(5, 7, d)
    np.testing.assert_allclose(np.asarray(butterfly_apply(x, ang)), np.asarray(x))


def test_matrix_action_agreement():
    d = 64
    ang = rand_angles(6, num_stages(d), d)
    x = rand_x(7, 5, d)
    b = np.asarray(butterfly_matrix(ang, d))
    np.testing.assert_allclose(
        np.asarray(butterfly_apply(x, ang)), np.asarray(x) @ b.T, atol=1e-4
    )


def test_param_count_matches_paper():
    # d=512: 512/2 * log2(512) = 2304 angles per transform (§3.5 counts
    # "512 log2 512 = 4608" for the in+out pair).
    d = 512
    ang = rand_angles(8, num_stages(d), d)
    assert ang.size == d // 2 * int(math.log2(d)) == 2304


@settings(deadline=None, max_examples=25)
@given(
    logd=st.integers(min_value=1, max_value=8),
    rows=st.integers(min_value=1, max_value=70),
    depth_frac=st.floats(min_value=0.1, max_value=1.0),
    transpose=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref(logd, rows, depth_frac, transpose, seed):
    d = 1 << logd
    depth = max(1, int(round(depth_frac * logd)))
    ang = rand_angles(seed, depth, d)
    x = rand_x(seed + 1, rows, d)
    got = butterfly_apply_pallas(x, ang, transpose=transpose, block_rows=16)
    want = butterfly_ref(x, ang, transpose=transpose)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_pallas_row_padding_path():
    """Rows not divisible by the block get padded and sliced back."""
    d = 16
    ang = rand_angles(9, num_stages(d), d)
    x = rand_x(10, 33, d)
    got = butterfly_apply_pallas(x, ang, block_rows=32)
    want = butterfly_ref(x, ang)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
