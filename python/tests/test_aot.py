"""AOT layer: BMOE container round-trip + manifest/artifact consistency.

The artifact-content tests only run when ../artifacts exists (created by
``make artifacts``); the container tests always run.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import bmoe_io
from compile.configs import PRESETS
from compile.model import init_params

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_bmoe_roundtrip(tmp_path):
    path = str(tmp_path / "t.bmoe")
    tensors = [
        ("a.b.c", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("scalar", np.float32(3.5).reshape(())),
        ("ints", np.array([[1, -2], [3, 4]], dtype=np.int32)),
        ("bytes", np.arange(5, dtype=np.uint8)),
    ]
    bmoe_io.write_bmoe(path, tensors)
    back = bmoe_io.read_bmoe(path)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, want), (_, got) in zip(tensors, back):
        np.testing.assert_array_equal(np.asarray(want), got)
        assert np.asarray(want).dtype == got.dtype


def test_bmoe_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "bad.bmoe")
    with open(path, "wb") as f:
        f.write(b"NOTBMOE")
    with pytest.raises(AssertionError):
        bmoe_io.read_bmoe(path)


def test_param_flatten_order_is_deterministic():
    cfg = PRESETS["tiny"]
    p1 = init_params(cfg, 0)
    p2 = init_params(cfg, 0)
    f1, t1 = jax.tree_util.tree_flatten(p1)
    f2, t2 = jax.tree_util.tree_flatten(p2)
    assert t1 == t2
    for a, b in zip(f1, f2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ART, a["file"])), a["name"]

    def test_train_step_io_arity(self, manifest):
        for a in manifest["artifacts"]:
            if a["kind"] != "train_step":
                continue
            n_in = len(a["inputs"])
            n_out = len(a["outputs"])
            # inputs: 3P + step + lr + tokens + targets
            p = (n_in - 4) // 3
            assert 3 * p + 4 == n_in, a["name"]
            # outputs: 3P + step + loss + ce + bal + load
            assert n_out == 3 * p + 5, (a["name"], n_in, n_out)

    def test_params_file_matches_manifest_names(self, manifest):
        for key, entry in manifest["params"].items():
            tensors = bmoe_io.read_bmoe(os.path.join(ART, entry["file"]))
            assert [n for n, _ in tensors] == entry["names"]
            for (name, arr), spec in zip(tensors, entry["tensors"]):
                assert list(arr.shape) == spec["shape"], name

    def test_train_step_param_names_match_export(self, manifest):
        """The executable's first P inputs must be exactly the exported
        param tensors, in order — the Rust driver depends on this."""
        by_cfg = {a["config"]: a for a in manifest["artifacts"] if a["kind"] == "train_step"}
        for cfg_name, art in by_cfg.items():
            entry = manifest["params"].get(cfg_name)
            if entry is None:
                continue
            p = (len(art["inputs"]) - 4) // 3
            art_param_names = [s["name"].removeprefix("params.") for s in art["inputs"][:p]]
            exported = [n.lstrip(".") for n in entry["names"]]
            assert art_param_names == exported, cfg_name

    def test_hlo_text_parses_by_keyword(self, manifest):
        # cheap sanity: every artifact is HLO text with an ENTRY module
        for a in manifest["artifacts"][:4]:
            with open(os.path.join(ART, a["file"])) as f:
                head = f.read(4096)
            assert "HloModule" in head, a["name"]
