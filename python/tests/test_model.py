"""L2 model: shapes, routing invariants, arch baselines, loss pieces."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import PRESETS, ModelConfig
from compile.model import (
    balance_loss,
    cross_entropy,
    init_ffn_params,
    init_params,
    lm_forward,
    lm_loss,
    moe_ffn_forward,
    topk_gate,
)

TINY = PRESETS["tiny"]


def rand_tokens(cfg, b=2, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, cfg.seq_len), 0, cfg.vocab)


class TestGate:
    def test_weights_rows_sum_to_one(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (40, 8))
        w, load = topk_gate(logits, 2)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(40), rtol=1e-5)

    def test_at_most_k_nonzero(self):
        logits = jax.random.normal(jax.random.PRNGKey(1), (33, 8))
        w, _ = topk_gate(logits, 2)
        nnz = np.count_nonzero(np.asarray(w), axis=-1)
        assert (nnz <= 2).all()

    def test_load_sums_to_one(self):
        logits = jax.random.normal(jax.random.PRNGKey(2), (64, 4))
        _, load = topk_gate(logits, 2)
        assert np.isclose(float(load.sum()), 1.0, atol=1e-5)

    def test_k1_selects_argmax(self):
        logits = jax.random.normal(jax.random.PRNGKey(3), (10, 5))
        w, _ = topk_gate(logits, 1)
        np.testing.assert_array_equal(
            np.argmax(np.asarray(w), -1), np.argmax(np.asarray(logits), -1)
        )


@pytest.mark.parametrize("name", ["tiny", "tiny_standard", "tiny_dense"])
def test_moe_ffn_shapes(name):
    cfg = PRESETS[name]
    p = init_ffn_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.seq_len, cfg.d_model))
    y, load = moe_ffn_forward(x, p, cfg)
    assert y.shape == x.shape


def test_moe_ffn_pallas_matches_jnp():
    cfg = TINY
    p = init_ffn_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.seq_len, cfg.d_model))
    y_ref, _ = moe_ffn_forward(x, p, cfg, use_pallas=False)
    y_pal, _ = moe_ffn_forward(x, p, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["tiny", "tiny_standard", "tiny_dense"])
def test_lm_forward_shapes(name):
    cfg = PRESETS[name]
    params = init_params(cfg, 0)
    toks = rand_tokens(cfg)
    logits, loads = lm_forward(params, toks, cfg)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)
    assert loads.shape[0] == cfg.n_blocks


def test_lm_loss_finite_and_near_uniform_at_init():
    cfg = TINY
    params = init_params(cfg, 0)
    toks = rand_tokens(cfg)
    loss, (ce, bal, loads) = lm_loss(params, toks, toks, cfg)
    assert np.isfinite(float(loss))
    # near-uniform logits at init => CE close to log(V)
    assert abs(float(ce) - np.log(cfg.vocab)) < 1.0


def test_balance_loss_zero_at_uniform():
    cfg = TINY
    loads = jnp.full((cfg.n_blocks, cfg.n_experts), 1.0 / cfg.n_experts)
    assert float(balance_loss(loads, cfg)) < 1e-12


def test_balance_loss_positive_when_skewed():
    cfg = TINY
    loads = jnp.zeros((cfg.n_blocks, cfg.n_experts)).at[:, 0].set(1.0)
    assert float(balance_loss(loads, cfg)) > 0.1


def test_cross_entropy_perfect_prediction():
    logits = jnp.full((1, 3, 5), -30.0)
    targets = jnp.array([[1, 2, 3]])
    logits = logits.at[0, 0, 1].set(30.0).at[0, 1, 2].set(30.0).at[0, 2, 3].set(30.0)
    assert float(cross_entropy(logits, targets)) < 1e-5


def test_experts_differ_at_init():
    """Random angle init (eq. 7) must break symmetry: different experts
    produce different outputs on the same input."""
    cfg = TINY
    p = init_ffn_params(cfg, jax.random.PRNGKey(0))
    from compile.kernels.ref import orbit_expert_ref
    from compile.quant import quantize_ste

    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model))
    wq = quantize_ste(p["w_base"])
    one = jnp.float32(1.0)
    y0 = orbit_expert_ref(x, p["theta"][0], wq, one, p["phi"][0])
    y1 = orbit_expert_ref(x, p["theta"][1], wq, one, p["phi"][1])
    assert float(jnp.max(jnp.abs(y0 - y1))) > 1e-4


def test_static_rotation_config_stops_gradients():
    cfg = PRESETS["tiny_static"]
    params = init_params(cfg, 0)
    toks = rand_tokens(cfg, b=1)
    grads = jax.grad(lambda p: lm_loss(p, toks, toks, cfg)[0])(params)
    for blk in grads["blocks"]:
        assert float(jnp.abs(blk["ffn"]["theta"]).max()) == 0.0
        assert float(jnp.abs(blk["ffn"]["phi"]).max()) == 0.0
    # but the substrate still learns
    assert float(jnp.abs(grads["blocks"][0]["ffn"]["w_base"]).max()) > 0.0


def test_learned_rotation_config_has_rotation_grads():
    cfg = TINY
    params = init_params(cfg, 0)
    toks = rand_tokens(cfg, b=1)
    grads = jax.grad(lambda p: lm_loss(p, toks, toks, cfg)[0])(params)
    assert float(jnp.abs(grads["blocks"][0]["ffn"]["theta"]).max()) > 0.0


def test_config_validation():
    with pytest.raises(AssertionError):
        ModelConfig(name="bad", d_model=48).validate()
    with pytest.raises(AssertionError):
        ModelConfig(name="bad", top_k=9, n_experts=4).validate()
