"""Generate the checked-in cross-language model fixture
``rust/tests/fixtures/tiny_model.bmoe``.

Writes a tiny multi-layer native model in the ``.bmoe`` model-artifact
format (DESIGN.md §3) through ``compile.bmoe_io`` — the normative python
writer — plus ``expected.*`` tensors holding reference logits computed
by a numpy mirror of the Rust native engine
(``NativeLmBackend::step``): each context token's embedding row runs
the L residual ButterflyMoE blocks independently (top-k gate → θᵀx →
ternary substrate GEMV → φ → GELU → w_down per block), the per-token
feature rows are folded left-to-right into a running mean, and the
readout scores of that mean are the logits.  The per-token function is
what makes chunked prefill bit-invariant on the Rust side (DESIGN.md
§2), so the mirror must be per-token too.

The Rust side (``rust/tests/artifact.rs``) loads this file via both
heap and mmap loaders, asserts the two are bitwise identical, and pins
its logits against ``expected.logits`` within a float tolerance (the
numpy mirror does not reproduce Rust's dot-product lane association
bit-for-bit; structural drift — wrong stage order, wrong bit layout —
blows far past the tolerance).

Run from the repo root:  python3 python/tests/make_artifact_fixture.py
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile import bmoe_io  # noqa: E402

F32 = np.float32

# fixture shape (small on purpose: the file is checked into git)
VOCAB, SEQ_LEN = 32, 16
D, DFF, E, TOP_K, L = 16, 32, 4, 2, 2
DEPTH_IN, DEPTH_OUT = 4, 5  # log2(16), log2(32)
SEED = 20260728

OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures", "tiny_model.bmoe"
)


def bf_apply(x, cs, d, depth, transpose=False):
    """Mirror of rust Butterfly::apply / apply_transpose: stage l pairs
    (base+off, base+off+stride) with angle index j walking in the same
    order; transpose runs stages reversed with negated sines."""
    x = x.astype(F32).copy()
    stages = range(depth - 1, -1, -1) if transpose else range(depth)
    for l in stages:
        stride = 1 << l
        table = cs[l]  # (d/2, 2) float32
        j = 0
        base = 0
        while base < d:
            for off in range(stride):
                lo, hi = base + off, base + off + stride
                c = table[j, 0]
                s = F32(-table[j, 1]) if transpose else table[j, 1]
                a, b = x[lo], x[hi]
                x[lo] = F32(c * a - s * b)
                x[hi] = F32(s * a + c * b)
                j += 1
            base += 2 * stride
    return x


def gelu(x):
    c = F32(0.7978845608028654)
    x = x.astype(F32)
    return (F32(0.5) * x * (F32(1.0) + np.tanh(c * (x + F32(0.044715) * x * x * x)))).astype(F32)


def softmax(v):
    v = v.astype(F32)
    e = np.exp(v - v.max())
    return (e / e.sum()).astype(F32)


class Layer:
    def __init__(self, rng):
        # gate scaled up vs the usual 1/sqrt(D) init so routing margins
        # are far above f32 association noise (the fixture must pin the
        # same expert selection in numpy and rust)
        self.gate = rng.standard_normal((E, D)).astype(F32)
        self.signs = rng.integers(-1, 2, size=(DFF, D)).astype(np.int8)
        self.gamma = F32(abs(rng.standard_normal()) * 0.05 + 0.02)
        self.theta = (rng.standard_normal((E, DEPTH_IN, D // 2)) * 0.5).astype(F32)
        self.phi = (rng.standard_normal((E, DEPTH_OUT, DFF // 2)) * 0.5).astype(F32)
        self.theta_cs = np.stack(
            [np.cos(self.theta), np.sin(self.theta)], axis=-1
        ).astype(F32)
        self.phi_cs = np.stack([np.cos(self.phi), np.sin(self.phi)], axis=-1).astype(F32)
        self.w_down = (rng.standard_normal((D, DFF)) / np.sqrt(DFF)).astype(F32)

    def planes(self):
        """Bitplane words: word wi bit b of a row is column wi*64 + b —
        the BitplaneTernary layout.  Returns u8 views (rows, wpr*8)."""
        wpr = (D + 63) // 64  # = 1 at this shape
        plus = np.zeros((DFF, wpr), dtype="<u8")
        minus = np.zeros((DFF, wpr), dtype="<u8")
        for r in range(DFF):
            for c in range(D):
                if self.signs[r, c] == 1:
                    plus[r, c // 64] |= np.uint64(1) << np.uint64(c % 64)
                elif self.signs[r, c] == -1:
                    minus[r, c // 64] |= np.uint64(1) << np.uint64(c % 64)
        return (
            plus.view(np.uint8).reshape(DFF, wpr * 8),
            minus.view(np.uint8).reshape(DFF, wpr * 8),
        )

    def route(self, x):
        """topk_gate mirror: softmax over gate logits, top-k by prob
        (stable sort, descending), renormalized.  Returns [(e, w)]
        ascending by expert index (the rust reduction order) plus the
        selection margin for the generator's tie guard."""
        logits = self.gate @ x.astype(F32)
        p = softmax(logits)
        order = np.argsort(-p, kind="stable")
        chosen = order[:TOP_K]
        margin = float(p[order[TOP_K - 1]] - p[order[TOP_K]]) if TOP_K < E else 1.0
        total = p[chosen].sum(dtype=F32)
        pairs = sorted((int(e), F32(p[e] / total)) for e in chosen)
        return pairs, margin

    def forward(self, x):
        """moe block mirror: experts -> gelu -> w_down.  Returns (y, margin)."""
        pairs, margin = self.route(x)
        h = np.zeros(DFF, dtype=F32)
        for e, w in pairs:
            xr = bf_apply(x, self.theta_cs[e], D, DEPTH_IN, transpose=True)
            mid = (self.signs.astype(F32) @ xr * self.gamma).astype(F32)
            out = bf_apply(mid, self.phi_cs[e], DFF, DEPTH_OUT, transpose=False)
            h = (h + w * out).astype(F32)
        g = gelu(h)
        y = (self.w_down @ g).astype(F32)
        return y, margin


def try_build(seed):
    """Build a model + reference outputs at `seed`; None if any margin
    (gate selection or argmax token) is too small to survive the float-
    association differences between numpy and the Rust engine."""
    rng = np.random.default_rng(seed)
    embed = (rng.standard_normal((VOCAB, D)) * 0.1).astype(F32)
    readout = (rng.standard_normal((VOCAB, D)) * 0.1).astype(F32)
    layers = [Layer(rng) for _ in range(L)]

    prompts = [
        [1, 2, 3],
        [31, 7, 7, 19, 4],
        [16, 0, 25, 9],
    ]

    # reference logits: one decode step per prompt (greedy_next
    # semantics).  Per-token mirror of NativeLmBackend::step: every
    # context token's embedding row runs the residual stack on its own,
    # then the feature rows fold left-to-right into a running mean.
    expected = np.zeros((len(prompts), VOCAB), dtype=F32)
    next_tokens = np.zeros(len(prompts), dtype=np.int32)
    for i, prompt in enumerate(prompts):
        ctx = prompt[-SEQ_LEN:]
        pool = np.zeros(D, dtype=F32)
        for t in ctx:
            x = embed[t % VOCAB].astype(F32).copy()
            for layer in layers:
                y, margin = layer.forward(x)
                if margin <= 2e-3:
                    return None
                x = (x + y).astype(F32)
            pool = (pool + x).astype(F32)
        x = (pool * F32(1.0 / len(ctx))).astype(F32)
        logits = (readout @ x).astype(F32)
        expected[i] = logits
        srt = np.sort(logits)
        # far above the ~1e-5 association noise between numpy and rust
        if srt[-1] - srt[-2] <= 2e-3:
            return None
        next_tokens[i] = int(np.argmax(logits))
    return embed, readout, layers, prompts, expected, next_tokens


def main():
    built = None
    for seed in range(SEED, SEED + 64):
        built = try_build(seed)
        if built is not None:
            print(f"using seed {seed}")
            break
    assert built is not None, "no seed in range produced robust margins"
    embed, readout, layers, prompts, expected, next_tokens = built

    manifest = {
        "format": "bmoe-model",
        "version": 1,
        "vocab": VOCAB,
        "seq_len": SEQ_LEN,
        "d_model": D,
        "d_ff": DFF,
        "n_layers": L,
        "n_experts": E,
        "top_k": TOP_K,
        "depth_in": DEPTH_IN,
        "depth_out": DEPTH_OUT,
    }
    tensors = [
        ("__model__", np.frombuffer(json.dumps(manifest).encode(), dtype=np.uint8)),
        ("embed", embed),
        ("readout", readout),
    ]
    for l, layer in enumerate(layers):
        plus, minus = layer.planes()
        tensors += [
            (f"layers.{l}.gate", layer.gate),
            (f"layers.{l}.substrate.gamma", np.asarray(layer.gamma, dtype=F32)),
            (f"layers.{l}.substrate.plus", plus),
            (f"layers.{l}.substrate.minus", minus),
            (f"layers.{l}.theta", layer.theta),
            (f"layers.{l}.theta_cs", layer.theta_cs),
            (f"layers.{l}.phi", layer.phi),
            (f"layers.{l}.phi_cs", layer.phi_cs),
            (f"layers.{l}.w_down", layer.w_down),
        ]
    # reference outputs for the rust side
    plen = max(len(p) for p in prompts)
    padded = np.full((len(prompts), plen), -1, dtype=np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    tensors += [
        ("expected.prompts", padded),
        ("expected.prompt_lens", np.array([len(p) for p in prompts], dtype=np.int32)),
        ("expected.logits", expected),
        ("expected.next_tokens", next_tokens),
    ]

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    bmoe_io.write_bmoe(OUT, tensors)
    size = os.path.getsize(OUT)
    print(f"wrote {OUT} ({size} bytes, {len(tensors)} tensors)")
    # self-check: the normative reader round-trips it
    back = dict(bmoe_io.read_bmoe(OUT))
    assert np.array_equal(back["expected.logits"], expected)
    assert bytes(back["__model__"].tobytes()) == json.dumps(manifest).encode()
    print(f"next tokens: {next_tokens.tolist()}")


if __name__ == "__main__":
    main()
