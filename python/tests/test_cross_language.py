"""Cross-language BMOE container compatibility: checkpoints written by
the Rust training driver must load in Python with identical semantics
(and could seed further jax fine-tuning).  Skips when no Rust artifacts
or checkpoints exist yet."""

import glob
import json
import os

import numpy as np
import pytest

from compile import bmoe_io

ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def rust_checkpoints():
    pats = ["runs/figs/*.bmoe", "runs/e2e/*.bmoe", "runs/*.bmoe"]
    out = []
    for p in pats:
        out.extend(glob.glob(os.path.join(ROOT, p)))
    return out


@pytest.mark.skipif(not rust_checkpoints(), reason="no rust checkpoints yet")
def test_rust_checkpoint_loads_and_is_well_formed():
    path = rust_checkpoints()[0]
    tensors = bmoe_io.read_bmoe(path)
    assert len(tensors) > 5
    names = [n for n, _ in tensors]
    assert any("w_base" in n or "w_up" in n for n in names), names[:5]
    for name, arr in tensors:
        assert np.isfinite(arr).all() if arr.dtype == np.float32 else True, name


@pytest.mark.skipif(not rust_checkpoints(), reason="no rust checkpoints yet")
def test_rust_checkpoint_matches_init_param_structure():
    """A trained checkpoint must carry exactly the init export's tensor
    names and shapes (the train step is shape-preserving)."""
    art = os.path.join(ROOT, "artifacts")
    ckpts = [p for p in rust_checkpoints() if "tiny_s" in os.path.basename(p)]
    init_path = os.path.join(art, "tiny.params.bmoe")
    if not ckpts or not os.path.exists(init_path):
        pytest.skip("need tiny checkpoint + init export")
    init = dict(bmoe_io.read_bmoe(init_path))
    trained = dict(bmoe_io.read_bmoe(ckpts[0]))
    assert set(trained) == set(init)
    for name in init:
        assert trained[name].shape == init[name].shape, name


# ---------------------------------------------------------------------------
# Golden bytes: the exact container layout, pinned on both sides
# ---------------------------------------------------------------------------

# The same bytes are embedded in rust/src/tensor/store.rs::golden_bytes_exact;
# regenerating them here proves the python writer has not drifted either.


def _golden_tensors():
    return [
        ("w", np.array([[1.0, -2.0, 3.0], [4.0, 5.0, 6.5]], dtype=np.float32)),
        ("ids", np.array([1, -2, 3, 4], dtype=np.int32)),
        ("packed", np.array([0, 127, 255], dtype=np.uint8)),
    ]


GOLDEN = bytes.fromhex(
    "424d4f45310003000000"
    "010077000202000000030000000000803f000000c00000404000008040"
    "0000a0400000d040"
    "030069647301010400000001000000feffffff0300000004000000"
    "06007061636b6564020103000000007fff"
)


def test_golden_bytes_exact(tmp_path):
    path = str(tmp_path / "golden.bmoe")
    bmoe_io.write_bmoe(path, _golden_tensors())
    with open(path, "rb") as f:
        got = f.read()
    assert got == GOLDEN, "python writer drifted from the pinned container bytes"
    back = bmoe_io.read_bmoe(path)
    assert [n for n, _ in back] == ["w", "ids", "packed"]
    assert np.array_equal(back[0][1], _golden_tensors()[0][1])
    assert np.array_equal(back[1][1], _golden_tensors()[1][1])
    assert np.array_equal(back[2][1], _golden_tensors()[2][1])


def test_model_fixture_is_well_formed():
    """The checked-in cross-language model fixture must stay readable by
    the normative python reader and keep its expected.* reference
    tensors (rust/tests/artifact.rs pins the logits against them)."""
    path = os.path.join(ROOT, "rust", "tests", "fixtures", "tiny_model.bmoe")
    assert os.path.exists(path), "regenerate with python3 python/tests/make_artifact_fixture.py"
    tensors = dict(bmoe_io.read_bmoe(path))
    manifest = json.loads(bytes(tensors["__model__"].tobytes()).decode())
    assert manifest["format"] == "bmoe-model" and manifest["version"] == 1
    for l in range(manifest["n_layers"]):
        for part in ("gate", "substrate.plus", "substrate.minus", "theta_cs", "phi_cs", "w_down"):
            assert f"layers.{l}.{part}" in tensors, part
    assert tensors["expected.logits"].shape == (
        tensors["expected.prompts"].shape[0],
        manifest["vocab"],
    )
    assert np.isfinite(tensors["expected.logits"]).all()
