"""Cross-language BMOE container compatibility: checkpoints written by
the Rust training driver must load in Python with identical semantics
(and could seed further jax fine-tuning).  Skips when no Rust artifacts
or checkpoints exist yet."""

import glob
import os

import numpy as np
import pytest

from compile import bmoe_io

ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def rust_checkpoints():
    pats = ["runs/figs/*.bmoe", "runs/e2e/*.bmoe", "runs/*.bmoe"]
    out = []
    for p in pats:
        out.extend(glob.glob(os.path.join(ROOT, p)))
    return out


@pytest.mark.skipif(not rust_checkpoints(), reason="no rust checkpoints yet")
def test_rust_checkpoint_loads_and_is_well_formed():
    path = rust_checkpoints()[0]
    tensors = bmoe_io.read_bmoe(path)
    assert len(tensors) > 5
    names = [n for n, _ in tensors]
    assert any("w_base" in n or "w_up" in n for n in names), names[:5]
    for name, arr in tensors:
        assert np.isfinite(arr).all() if arr.dtype == np.float32 else True, name


@pytest.mark.skipif(not rust_checkpoints(), reason="no rust checkpoints yet")
def test_rust_checkpoint_matches_init_param_structure():
    """A trained checkpoint must carry exactly the init export's tensor
    names and shapes (the train step is shape-preserving)."""
    art = os.path.join(ROOT, "artifacts")
    ckpts = [p for p in rust_checkpoints() if "tiny_s" in os.path.basename(p)]
    init_path = os.path.join(art, "tiny.params.bmoe")
    if not ckpts or not os.path.exists(init_path):
        pytest.skip("need tiny checkpoint + init export")
    init = dict(bmoe_io.read_bmoe(init_path))
    trained = dict(bmoe_io.read_bmoe(ckpts[0]))
    assert set(trained) == set(init)
    for name in init:
        assert trained[name].shape == init[name].shape, name
