"""Training step: AdamW math, descent on a fixed batch, arch variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import PRESETS
from compile.model import init_params
from compile.train import (
    ADAM_B1,
    ADAM_B2,
    ADAM_EPS,
    WEIGHT_DECAY,
    _adamw_update,
    init_opt_state,
    make_eval,
    make_train_step,
)

TINY = PRESETS["tiny"]


def test_adamw_update_matches_numpy():
    p = jnp.array([1.0, -2.0])
    g = jnp.array([0.5, 0.25])
    m = jnp.array([0.1, 0.0])
    v = jnp.array([0.01, 0.0])
    lr = 0.1
    t = 3.0
    bc1, bc2 = 1 - ADAM_B1**t, 1 - ADAM_B2**t
    new_p, new_m, new_v = _adamw_update(p, g, m, v, lr, bc1, bc2)
    m_ = ADAM_B1 * np.asarray(m) + (1 - ADAM_B1) * np.asarray(g)
    v_ = ADAM_B2 * np.asarray(v) + (1 - ADAM_B2) * np.asarray(g) ** 2
    want = np.asarray(p) - lr * (
        (m_ / bc1) / (np.sqrt(v_ / bc2) + ADAM_EPS) + WEIGHT_DECAY * np.asarray(p)
    )
    np.testing.assert_allclose(np.asarray(new_p), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_m), m_, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_v), v_, rtol=1e-6)


def _run_steps(cfg, n, lr=3e-3, seed=0):
    params = init_params(cfg, seed)
    m, v = init_opt_state(params)
    step = jnp.int32(0)
    fn = jax.jit(make_train_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(7), (8, cfg.seq_len), 0, cfg.vocab)
    targets = jnp.roll(toks, -1, axis=1)
    losses = []
    for _ in range(n):
        params, m, v, step, loss, ce, bal, load = fn(
            params, m, v, step, jnp.float32(lr), toks, targets
        )
        losses.append(float(loss))
    return losses, params


@pytest.mark.parametrize("name", ["tiny", "tiny_static", "tiny_standard", "tiny_dense"])
def test_fixed_batch_descent(name):
    """Every architecture must overfit a single batch (loss drops >10%)."""
    losses, _ = _run_steps(PRESETS[name], 12)
    assert all(np.isfinite(losses))
    assert losses[-1] < 0.9 * losses[0], losses


def test_step_counter_and_load_outputs():
    cfg = TINY
    params = init_params(cfg, 0)
    m, v = init_opt_state(params)
    fn = jax.jit(make_train_step(cfg))
    toks = jnp.zeros((4, cfg.seq_len), jnp.int32)
    params, m, v, step, loss, ce, bal, load = fn(
        params, m, v, jnp.int32(0), jnp.float32(1e-3), toks, toks
    )
    assert int(step) == 1
    assert load.shape == (cfg.n_experts,)
    assert np.isclose(float(load.sum()), 1.0, atol=1e-5)


def test_rotations_move_when_learned():
    cfg = TINY
    _, params = _run_steps(cfg, 4)
    p0 = init_params(cfg, 0)
    delta = float(
        jnp.abs(params["blocks"][0]["ffn"]["theta"] - p0["blocks"][0]["ffn"]["theta"]).max()
    )
    assert delta > 1e-6


def test_rotations_frozen_when_static():
    cfg = PRESETS["tiny_static"]
    _, params = _run_steps(cfg, 4)
    p0 = init_params(cfg, 0)
    np.testing.assert_array_equal(
        np.asarray(params["blocks"][0]["ffn"]["theta"]),
        np.asarray(p0["blocks"][0]["ffn"]["theta"]),
    )


def test_eval_matches_loss_pieces():
    cfg = TINY
    params = init_params(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq_len), 0, cfg.vocab)
    ce, total = jax.jit(make_eval(cfg))(params, toks, toks)
    assert float(total) >= float(ce) - 1e-6  # balance term is nonneg
    assert np.isfinite(float(ce))
