//! End-to-end training driver (the repo's headline validation run).
//!
//! Trains the ButterflyMoE transformer LM — all compute in the single
//! AOT-compiled train-step HLO (fwd + bwd + AdamW, with STE ternary
//! quantization and learned rotations inside) — on the synthetic
//! multi-domain corpus, from the Rust driver with zero Python.
//!
//! Also trains the dense and standard-MoE baselines for the accuracy
//! comparison (§4.1's "equals dense accuracy" claim), writes loss-curve
//! CSVs, and reports the quantization-error trajectory (Fig. 4's metric)
//! on the trained checkpoint.
//!
//! Run: `cargo run --release --example train_lm -- [--config small]
//!       [--steps 300] [--out runs/e2e]`
//! Results are recorded in EXPERIMENTS.md.

use std::path::Path;

use butterfly_moe::cli::Args;
use butterfly_moe::config::RuntimeConfig;
use butterfly_moe::quant::weight_quant_error;
use butterfly_moe::runtime::Engine;
use butterfly_moe::train::Trainer;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let config = args.flag_or("config", "tiny");
    let steps: usize = args.flag_parse("steps")?.unwrap_or(300);
    let out = args.flag_or("out", "runs/e2e");
    let baseline_steps: usize = args.flag_parse("baseline-steps")?.unwrap_or(steps);

    let rt = RuntimeConfig {
        steps,
        lr: 3e-3,
        warmup_steps: steps / 10,
        checkpoint_every: 0,
        out_dir: out.clone(),
        ..Default::default()
    };
    let engine = Engine::new(Path::new("artifacts"))?;

    println!("== e2e: training '{config}' for {steps} steps ==");
    let trainer = Trainer::new(&engine, rt.clone());
    let report = trainer.run(&config, None)?;
    report.write_csv(Path::new(&out).join(format!("{config}_loss.csv")).as_path())?;
    report.save_checkpoint(Path::new(&out).join(format!("{config}_final.bmoe")).as_path())?;
    let held_out = trainer.eval(&config, &report.final_params, 8)?;
    println!(
        "{config}: loss {:.4} -> {:.4} | held-out CE {:.4} | {:.1}s ({:.0} ms/step)",
        report.logs[0].loss,
        report.final_loss(),
        held_out,
        report.total_secs,
        1e3 * report.total_secs / steps as f64,
    );

    // Fig. 4 weight-space metric on the trained substrate(s)
    let mut w_errs = Vec::new();
    for (name, v) in report.param_names.iter().zip(&report.final_params) {
        if name.contains("w_base") {
            if let Ok(t) = v.as_f32() {
                w_errs.push((name.clone(), weight_quant_error(t)));
            }
        }
    }
    if !w_errs.is_empty() {
        println!("trained substrate quantization error (rel MSE):");
        for (n, e) in &w_errs {
            println!("  {n}: {:.2}%", 100.0 * e);
        }
    }

    // Baselines trained on the same corpus for the accuracy comparison
    let mut summary = vec![(config.clone(), held_out)];
    for base in ["tiny_standard", "tiny_dense"] {
        if config != "tiny" || engine.manifest.configs.get(base).is_none() {
            continue;
        }
        let rt_b = RuntimeConfig {
            steps: baseline_steps,
            ..rt.clone()
        };
        let mut t = Trainer::new(&engine, rt_b);
        t.quiet = true;
        println!("== baseline: {base} ({baseline_steps} steps) ==");
        let rep = t.run(base, None)?;
        rep.write_csv(Path::new(&out).join(format!("{base}_loss.csv")).as_path())?;
        // standard/dense have no eval artifact in the ci profile; report
        // the tail training CE as the comparable number.
        let tail = rep.tail_ce(20);
        println!("{base}: final loss {:.4}, tail CE {:.4}", rep.final_loss(), tail);
        summary.push((base.to_string(), tail));
    }

    println!("\n== summary (lower is better) ==");
    for (name, ce) in &summary {
        println!("  {name:<16} CE {ce:.4}");
    }
    println!("loss curves + checkpoints in {out}/");
    Ok(())
}
