//! Serving demo: N concurrent clients, each streaming a multi-token
//! completion (half greedy, half temperature-sampled) from the
//! continuous-batching coordinator — the serving-systems view of
//! ButterflyMoE.
//!
//! Mixed prompt budgets show the headline property of session
//! scheduling: short requests join the running batch, stream out, and
//! finish while long batch-mates are still decoding.
//!
//! Run: `cargo run --release --example serve -- [--config tiny]
//!       [--clients 8] [--sessions 4] [--max-batch 16] [--native]
//!       [--expert-cache-mb 8] [--workers 4] [--layers 2]
//!       [--model model.bmoe] [--load mmap|heap]`
//! (`--native` serves the pure-rust multi-layer LM; no artifacts needed.
//! `--model` serves a packed .bmoe model artifact — mmap-loaded by
//! default, so cold start is page faults, not deserialization.
//! `--expert-cache-mb` attaches the expert-residency cache and
//! `--workers` sets hot-path parallelism — 0/default = all cores;
//! decoded streams are identical for every worker count and load mode.)

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::artifact::{synthesize, LoadMode, ModelArtifact, SynthSpec};
use butterfly_moe::cli::Args;
use butterfly_moe::coordinator::{
    collect_stream, Backend, Coordinator, GenerateRequest, NativeLmBackend, PjrtLmBackend,
    SamplingParams, SchedulerConfig, StopCriteria,
};
use butterfly_moe::moe::MoeLayer;
use butterfly_moe::util::{stats, Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let config = args.flag_or("config", "tiny");
    let clients: usize = args.flag_parse("clients")?.unwrap_or(8);
    let sessions: usize = args.flag_parse("sessions")?.unwrap_or(4);
    let max_batch: usize = args.flag_parse("max-batch")?.unwrap_or(16);
    let max_wait_ms: u64 = args.flag_parse("max-wait-ms")?.unwrap_or(2);

    let backend: Arc<dyn Backend> = if args.has_switch("native") {
        let workers = butterfly_moe::parallel::resolve_workers(
            args.flag_parse("workers")?.unwrap_or(0),
        );
        let pool = Arc::new(butterfly_moe::parallel::WorkerPool::new(workers));
        println!("hot-path workers: {workers} (token streams are worker-count invariant)");
        let cache_mb: f64 = args.flag_parse("expert-cache-mb")?.unwrap_or(0.0);
        let cache_bytes = (cache_mb * 1048576.0) as usize;
        let backend = if let Some(model_path) = args.flag("model") {
            let mode = LoadMode::parse(&args.flag_or("load", "mmap"))?;
            let artifact = ModelArtifact::load(Path::new(model_path), mode)?;
            let b = NativeLmBackend::from_artifact(&artifact, max_batch, Some(pool), cache_bytes)?;
            let (borrowed, copied) = artifact.zero_copy_stats();
            println!(
                "== native LM from {model_path}: {} layers, {} ({} load; \
                 {borrowed} tensors zero-copy, {copied} copied) ==",
                artifact.manifest.n_layers,
                butterfly_moe::util::human_bytes(artifact.file_bytes() as f64),
                mode.name(),
            );
            b
        } else {
            let n_layers: usize = args.flag_parse("layers")?.unwrap_or(1);
            let model = synthesize(&SynthSpec::serve_default(n_layers, 0xBE));
            println!("== native LM backend ({n_layers} residual blocks, no artifacts) ==");
            NativeLmBackend::from_synth(model, max_batch, Some(pool), cache_bytes)
        };
        if cache_bytes > 0 {
            // a budget that splits below one byte per layer attaches no
            // cache at all; both disabled forms are an input error here
            let cache = backend.layers()[0].expert_cache();
            anyhow::ensure!(
                cache.is_some_and(|c| c.enabled()),
                "--expert-cache-mb {cache_mb} splits below one expert working set per layer"
            );
            println!(
                "   expert cache: {} experts max per layer",
                cache.unwrap().capacity_experts()
            );
        }
        Arc::new(backend)
    } else {
        let (b, _join) = PjrtLmBackend::start(Path::new("artifacts"), &config, None)?;
        println!("== PJRT LM backend (config={config}) ==");
        Arc::new(b)
    };
    let vocab = backend.vocab();
    println!(
        "backend {} | max_batch<={max_batch} wait<={max_wait_ms}ms | {clients} clients x {sessions} sessions",
        backend.name()
    );
    // warmup: drive every compiled batch bucket before timing, so XLA
    // bucket compilation stays out of the measured window
    butterfly_moe::coordinator::warm(backend.as_ref())?;
    let coord = Coordinator::start(
        backend,
        SchedulerConfig::new(max_batch, Duration::from_millis(max_wait_ms)),
    );

    let t0 = Instant::now();
    let results = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let coord = coord.clone();
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(0x5E12E + c as u64);
                let mut lines = Vec::new();
                let mut ttfts = Vec::new();
                for s in 0..sessions {
                    let plen = 4 + rng.below(12);
                    let prompt: Vec<i32> =
                        (0..plen).map(|_| rng.below(vocab) as i32).collect();
                    // odd sessions sample, even sessions decode greedily;
                    // alternate short and long token budgets
                    let max_new = if s % 2 == 0 { 8 } else { 48 };
                    let sampling = if s % 2 == 0 {
                        SamplingParams::greedy()
                    } else {
                        SamplingParams::top_k(0.8, 40, (c * 1000 + s) as u64)
                    };
                    let req = GenerateRequest {
                        prompt,
                        sampling,
                        stop: StopCriteria::max_tokens(max_new),
                    };
                    let rx = coord.submit(req);
                    let done = collect_stream(&rx, Duration::from_secs(120))
                        .expect("session must terminate");
                    if let Some(ttft) = done.ttft {
                        ttfts.push(ttft.as_secs_f64());
                    }
                    lines.push(format!(
                        "client {c} session {s}: {} tokens ({}) in {:.1} ms, first {:?} ...",
                        done.tokens.len(),
                        done.reason,
                        done.total.as_secs_f64() * 1e3,
                        &done.tokens[..done.tokens.len().min(6)],
                    ));
                }
                (lines, ttfts)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect::<Vec<_>>()
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut ttfts = Vec::new();
    for (lines, t) in &results {
        for l in lines {
            println!("  {l}");
        }
        ttfts.extend_from_slice(t);
    }
    let snap = coord.metrics.snapshot();
    println!("\n== results ==");
    println!(
        "  {} sessions ({} tokens) in {wall:.1}s -> {:.0} tok/s sustained",
        snap.responses, snap.tokens, snap.tokens as f64 / wall
    );
    println!(
        "  client-side ttft p50 {:.2} ms | p99 {:.2} ms",
        1e3 * stats::percentile(&ttfts, 50.0),
        1e3 * stats::percentile(&ttfts, 99.0),
    );
    println!("  coordinator: {}", snap.summary());
    coord.shutdown();
    std::process::exit(0); // PJRT engine thread would otherwise hold the process
}
