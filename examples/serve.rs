//! Serving demo: start the coordinator over the AOT-compiled LM, drive
//! it with a Poisson open-loop load, report latency percentiles and
//! throughput — the serving-systems view of ButterflyMoE.
//!
//! Run: `cargo run --release --example serve -- [--config tiny]
//!       [--rps 200] [--seconds 10] [--workers 2] [--max-batch 16]`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::cli::Args;
use butterfly_moe::coordinator::{Coordinator, PjrtLmBackend};
use butterfly_moe::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let config = args.flag_or("config", "tiny");
    let rps: f64 = args.flag_parse("rps")?.unwrap_or(200.0);
    let seconds: f64 = args.flag_parse("seconds")?.unwrap_or(10.0);
    let workers: usize = args.flag_parse("workers")?.unwrap_or(2);
    let max_batch: usize = args.flag_parse("max-batch")?.unwrap_or(16);
    let max_wait_ms: u64 = args.flag_parse("max-wait-ms")?.unwrap_or(5);

    println!("== starting coordinator (config={config}, {workers} workers, batch<= {max_batch}, wait<={max_wait_ms}ms) ==");
    let (backend, _join) = PjrtLmBackend::start(Path::new("artifacts"), &config, None)?;
    let vocab = 512; // tiny/small prompts sample below this
    let coord = Coordinator::start(
        Arc::new(backend),
        max_batch,
        Duration::from_millis(max_wait_ms),
        workers,
    );

    // warmup: compile all buckets before measuring
    for b in [1usize, 3, 9] {
        let rxs: Vec<_> = (0..b).map(|_| coord.submit(vec![1, 2, 3])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
    }

    println!("== open-loop Poisson load: {rps} req/s for {seconds}s ==");
    let mut rng = Rng::new(0x5E12E);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut next_arrival = 0.0f64;
    let mut submitted = 0u64;
    while t0.elapsed().as_secs_f64() < seconds {
        let now = t0.elapsed().as_secs_f64();
        if now >= next_arrival {
            let len = 4 + rng.below(12);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(vocab) as i32).collect();
            pending.push(coord.submit(prompt));
            submitted += 1;
            next_arrival += rng.exponential(rps);
        } else {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    // drain
    let mut latencies = Vec::with_capacity(pending.len());
    for rx in pending {
        let resp = rx.recv_timeout(Duration::from_secs(120))?;
        latencies.push(resp.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();

    use butterfly_moe::util::stats;
    println!("\n== results ==");
    println!("  submitted {submitted} requests in {wall:.1}s -> {:.0} req/s served", submitted as f64 / wall);
    println!(
        "  latency p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms | max {:.1} ms",
        1e3 * stats::percentile(&latencies, 50.0),
        1e3 * stats::percentile(&latencies, 95.0),
        1e3 * stats::percentile(&latencies, 99.0),
        1e3 * latencies.iter().cloned().fold(0.0, f64::max),
    );
    println!("  coordinator: {}", coord.metrics.snapshot().summary());
    coord.shutdown();
    std::process::exit(0); // engine thread would otherwise hold the process
}
