//! Quickstart: the 60-second tour of the public API.
//!
//!   1. memory math — why butterfly orbits beat dense experts (Prop. 1/2)
//!   2. the native edge engine — build a layer, route a batch
//!   3. model artifacts — pack a multi-layer model, mmap it back,
//!      check bitwise parity (the `bmoe pack-model` / `serve --model` flow)
//!   4. the AOT path — load the jax-compiled graph and cross-check it
//!
//! Run: `cargo run --release --example quickstart`
//! (Step 4 is skipped politely if `make artifacts` hasn't been run.)

use std::path::Path;

use butterfly_moe::artifact::{synthesize, LoadMode, Mmap, ModelArtifact, SynthSpec};
use butterfly_moe::memmodel::{butterfly_bytes, LayerShape, Method};
use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer};
use butterfly_moe::runtime::{Engine, Value};
use butterfly_moe::tensor::Tensor;
use butterfly_moe::util::{human_bytes, Rng};

fn main() -> anyhow::Result<()> {
    // ------------------------------------------------------------------
    // 1. The headline math (Table 1 / Fig. 3)
    // ------------------------------------------------------------------
    let shape = LayerShape::paper(); // d_model=512, d_ff=2048
    println!("== 1. memory scaling (d=512, d_ff=2048) ==");
    for n in [8usize, 64, 256] {
        println!(
            "  {n:>3} experts: standard {:>10}  butterfly {:>9}  ({:.0}x)",
            human_bytes(Method::StandardMoe.bytes(n, shape)),
            human_bytes(butterfly_bytes(n, shape)),
            Method::ButterflyMoe.ratio(n, shape),
        );
    }

    // ------------------------------------------------------------------
    // 2. Native edge engine: experts as orbits of one ternary substrate
    // ------------------------------------------------------------------
    println!("\n== 2. native engine forward ==");
    let mut rng = Rng::new(42);
    let layer = ButterflyMoeLayer::random(128, 512, 8, 2, None, &mut rng);
    let t = 4;
    let x = Tensor::rand_normal(&[t, 128], 1.0, &mut rng);
    let mut y = vec![0.0f32; t * 128];
    let loads = layer.forward(&x.data, t, &mut y);
    println!(
        "  8 experts, {} of expert storage (vs {} dense)",
        human_bytes(layer.expert_bytes() as f64),
        human_bytes(8.0 * 512.0 * 128.0 * 4.0),
    );
    println!(
        "  routed {t} tokens; per-expert load: {:?}",
        loads.iter().map(|l| format!("{l:.2}")).collect::<Vec<_>>()
    );
    println!("  y[0][..4] = {:?}", &y[..4]);

    // ------------------------------------------------------------------
    // 3. Model artifacts: pack -> mmap load -> bitwise parity
    // ------------------------------------------------------------------
    println!("\n== 3. model artifacts (pack-model / serve --model) ==");
    let spec = SynthSpec {
        d_model: 128,
        d_ff: 512,
        n_experts: 8,
        top_k: 2,
        n_layers: 2,
        vocab: 512,
        seq_len: 32,
        depth: None,
        seed: 42,
    };
    let model = synthesize(&spec);
    let dir = std::env::temp_dir().join("bmoe_quickstart");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("quickstart.bmoe");
    let stats = model.pack(&path)?;
    let mode = if Mmap::supported() { LoadMode::Mmap } else { LoadMode::Heap };
    let artifact = ModelArtifact::load(&path, mode)?;
    let loaded = artifact.build_layers()?;
    // parity: the loaded stack performs bit-identical arithmetic to the
    // in-memory model it was packed from
    let xq = Tensor::rand_normal(&[4, 128], 1.0, &mut rng);
    let mut y_mem = vec![0.0f32; 4 * 128];
    let mut y_loaded = vec![0.0f32; 4 * 128];
    model.layers[0].forward(&xq.data, 4, &mut y_mem);
    loaded[0].forward(&xq.data, 4, &mut y_loaded);
    assert_eq!(y_mem, y_loaded, "loaded model must be bit-identical");
    let (borrowed, copied) = artifact.zero_copy_stats();
    println!(
        "  packed {} layers into {} ({} in {} tensors, {} pads)",
        spec.n_layers,
        path.display(),
        human_bytes(stats.file_bytes as f64),
        stats.tensors,
        stats.pads,
    );
    println!(
        "  {} load: {borrowed} tensors zero-copy, {copied} copied; \
         forward parity vs in-memory model: bitwise ✓",
        mode.name()
    );

    // ------------------------------------------------------------------
    // 4. AOT path: the jax graph (with Pallas kernels) via PJRT
    // ------------------------------------------------------------------
    println!("\n== 4. AOT artifact execution ==");
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("  (skipped — run `make artifacts` first)");
        return Ok(());
    }
    let engine = Engine::new(dir)?;
    let cfg = engine.manifest.config("tiny")?.clone();
    let mut inputs = engine.load_params("tiny.ffn")?;
    let mut rng = Rng::new(7);
    let xa = Tensor::rand_normal(&[16, cfg.d_model], 1.0, &mut rng);
    inputs.push(Value::F32(xa.clone()));
    let out = engine.run("tiny__moe_fwd_t16", &inputs)?;
    let ya = out[0].as_f32()?;
    println!(
        "  ran tiny__moe_fwd_t16 on {}: y shape {:?}, y[0][..4] = {:?}",
        engine.platform(),
        ya.shape,
        &ya.data[..4]
    );

    // cross-check against the native engine on the same weights
    let store =
        butterfly_moe::tensor::store::TensorStore::read(&dir.join("tiny.ffn.bmoe"))?;
    let native = ButterflyMoeLayer::from_store(&store, "ffn.", cfg.top_k)?;
    let mut yn = vec![0.0f32; 16 * cfg.d_model];
    native.forward(&xa.data, 16, &mut yn);
    let err = yn
        .iter()
        .zip(&ya.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  native-engine max |diff| vs AOT graph: {err:.2e}  (parity ✓)");
    Ok(())
}
