//! Edge deployment study (the paper's motivating scenario, §1/§3.2).
//!
//! For each device profile (RPi 5, Jetson Nano, ESP32):
//!   * how many experts fit (Table "devices"),
//!   * actually *instantiate* a ButterflyMoE layer at a large expert
//!     count on this machine, measure its real packed memory and its
//!     per-token latency with the native engine,
//!   * optionally attach an expert-residency cache and show the
//!     memory↔throughput dial: hot experts served from a materialized
//!     working set (bit-identical outputs) at a byte budget,
//!   * estimate per-inference energy on that device's DRAM (Table 3's
//!     model, per device).
//!
//! Run: `cargo run --release --example edge_deployment --
//!       [--experts 256] [--expert-cache-mb 16] [--workers 4]
//!       [--model model.bmoe] [--load mmap|heap]`
//! (accepts and ignores `--native`: this example is always native;
//! `--workers 0`/default = all cores, `--workers 1` = sequential —
//! outputs are bit-identical either way.  With `--model`, the layer
//! stack is mmap-loaded from a packed .bmoe artifact — the real edge
//! deployment flow: weights live on disk + page cache, and concurrent
//! processes share the substrate pages.)

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use butterfly_moe::artifact::{LoadMode, ModelArtifact};
use butterfly_moe::cli::Args;
use butterfly_moe::coordinator::{
    warm, Coordinator, GenerateRequest, NativeLmBackend, NativeMoeBackend, SamplingParams,
    SchedulerConfig,
};
use butterfly_moe::devices::ALL_DEVICES;
use butterfly_moe::energy::{butterfly_moe_energy, standard_moe_energy};
use butterfly_moe::expertcache::ExpertCacheConfig;
use butterfly_moe::memmodel::{butterfly_bytes, cached_butterfly_bytes, LayerShape, Method};
use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer};
use butterfly_moe::tensor::Tensor;
use butterfly_moe::util::{human_bytes, Rng, Stopwatch};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let mut n_experts: usize = args.flag_parse("experts")?.unwrap_or(256);
    let cache_mb: f64 = args.flag_parse("expert-cache-mb")?.unwrap_or(0.0);
    let shape = LayerShape::paper();

    println!("== device deployability (d=512, d_ff=2048) ==");
    println!(
        "{:<14} {:>12} {:>14} {:>14} {:>14}",
        "device", "budget", "standard fits", "qmoe fits", "butterfly fits"
    );
    for dev in ALL_DEVICES {
        println!(
            "{:<14} {:>12} {:>14} {:>14} {:>14}",
            dev.name,
            human_bytes(dev.model_budget()),
            dev.max_experts(Method::StandardMoe, shape),
            dev.max_experts(Method::Qmoe, shape),
            dev.max_experts(Method::ButterflyMoe, shape),
        );
    }

    // ------------------------------------------------------------------
    // Instantiate a big orbit family for real (this is the point: 256
    // experts in a few MB — standard MoE would need 1 GB here), or
    // mmap-load a packed model artifact (the on-disk deployment flow)
    // ------------------------------------------------------------------
    let workers =
        butterfly_moe::parallel::resolve_workers(args.flag_parse("workers")?.unwrap_or(0));
    let pool = Arc::new(butterfly_moe::parallel::WorkerPool::new(workers));
    let mut rng = Rng::new(0xED6E);
    // shape of the layer actually measured below: the paper shape for
    // the synthetic build, the manifest's shape for a loaded artifact
    let mut lshape = shape;
    let (layer, cache, loaded): (Arc<dyn MoeLayer>, _, Option<Arc<NativeLmBackend>>) =
        if let Some(model_path) = args.flag("model") {
            let mode = LoadMode::parse(&args.flag_or("load", "mmap"))?;
            let sw = Stopwatch::start();
            let artifact = ModelArtifact::load(Path::new(model_path), mode)?;
            let cache_bytes = (cache_mb * 1048576.0) as usize;
            let backend =
                Arc::new(NativeLmBackend::from_artifact(&artifact, 8, Some(pool), cache_bytes)?);
            n_experts = artifact.manifest.n_experts;
            lshape = LayerShape {
                d_model: artifact.manifest.d_model,
                d_ff: artifact.manifest.d_ff,
            };
            let (borrowed, copied) = artifact.zero_copy_stats();
            println!("\n== loading {model_path} on this machine ==");
            println!(
                "  {} layers x {n_experts} experts, {} on disk; {} load in {:.1} ms \
                 ({borrowed} tensors zero-copy, {copied} copied)",
                artifact.manifest.n_layers,
                human_bytes(artifact.file_bytes() as f64),
                mode.name(),
                sw.millis(),
            );
            println!("  hot-path workers: {workers} (outputs are worker-count invariant)");
            let first = backend.layers()[0].clone();
            let cache = first.expert_cache().cloned();
            (first, cache, Some(backend))
        } else {
            println!("\n== instantiating {n_experts} experts on this machine ==");
            let sw = Stopwatch::start();
            let mut layer = ButterflyMoeLayer::random(512, 2048, n_experts, 2, None, &mut rng);
            layer.attach_worker_pool(pool);
            println!("  hot-path workers: {workers} (outputs are worker-count invariant)");
            let cache = (cache_mb > 0.0)
                .then(|| layer.attach_expert_cache(ExpertCacheConfig::with_budget_mb(cache_mb)));
            println!("  built in {:.2}s", sw.secs());
            (Arc::new(layer) as Arc<dyn MoeLayer>, cache, None)
        };
    println!(
        "  expert storage {} (Prop.-1 formula {}), vs standard {}",
        human_bytes(layer.expert_bytes() as f64),
        human_bytes(butterfly_bytes(n_experts, lshape)),
        human_bytes(Method::StandardMoe.bytes(n_experts, lshape)),
    );
    if let Some(c) = &cache {
        anyhow::ensure!(
            c.enabled(),
            "--expert-cache-mb {cache_mb} is smaller than one expert working set ({})",
            human_bytes(c.entry_bytes() as f64),
        );
        println!(
            "  expert cache: budget {} = {} resident experts max ({} working set each); \
             total with cache full: {}",
            human_bytes(c.budget_bytes() as f64),
            c.capacity_experts(),
            human_bytes(c.entry_bytes() as f64),
            human_bytes(cached_butterfly_bytes(n_experts, c.capacity_experts(), lshape)),
        );
    }

    // per-token latency of the Alg.-1 hot path (layer 0 of the stack)
    let t = 16;
    let (d, dff) = (layer.d_model(), layer.d_ff());
    let x = Tensor::rand_normal(&[t, d], 1.0, &mut rng);
    let mut h = vec![0.0f32; t * dff];
    // warmup + measure (cache cold: this is the pure synthesis path)
    layer.experts_forward(&x.data, t, &mut h);
    let sw = Stopwatch::start();
    let iters = 10;
    for _ in 0..iters {
        layer.experts_forward(&x.data, t, &mut h);
    }
    let per_token = sw.secs() / (iters * t) as f64;
    println!(
        "  expert mixture (synthesized): {:.2} ms/token ({:.0} tokens/s) on this CPU",
        per_token * 1e3,
        1.0 / per_token
    );

    // same workload with the residency cache admitted to steady state:
    // repeated routes make the batch's hottest experts resident, and the
    // fast path is bit-identical to synthesis (parity-tested)
    if let Some(c) = &cache {
        for _ in 0..16 {
            layer.experts_forward(&x.data, t, &mut h);
            c.tick();
        }
        let sw = Stopwatch::start();
        for _ in 0..iters {
            layer.experts_forward(&x.data, t, &mut h);
            c.tick();
        }
        let cached_per_token = sw.secs() / (iters * t) as f64;
        println!(
            "  expert mixture (cache warm):  {:.2} ms/token ({:.0} tokens/s) — {:.2}x, {}",
            cached_per_token * 1e3,
            1.0 / cached_per_token,
            per_token / cached_per_token,
            c.snapshot().summary(),
        );
    }

    // ------------------------------------------------------------------
    // Generation sessions on-device: the same layer behind the
    // continuous-batching coordinator, streaming multi-token completions
    // ------------------------------------------------------------------
    println!("\n== generation sessions over the native engine ==");
    let backend = match loaded {
        Some(b) => b, // the full multi-layer stack from the artifact
        None => Arc::new(NativeMoeBackend::new(layer.clone(), 512, 32, 8)),
    };
    let vocab = butterfly_moe::coordinator::Backend::vocab(backend.as_ref());
    warm(backend.as_ref())?; // pre-materializes the cache working set too
    let coord = Coordinator::start(backend, SchedulerConfig::new(8, Duration::from_millis(1)));
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            let prompt: Vec<i32> = (0..6).map(|_| rng.below(vocab) as i32).collect();
            let req = if i % 2 == 0 {
                GenerateRequest::greedy(prompt, 16)
            } else {
                GenerateRequest::greedy(prompt, 16)
                    .with_sampling(SamplingParams::temperature(0.9, i as u64))
            };
            coord.submit(req)
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let c = butterfly_moe::coordinator::collect_stream(&rx, Duration::from_secs(60))?;
        println!(
            "  session {i}: {} tokens ({}) ttft {:.2} ms total {:.2} ms",
            c.tokens.len(),
            c.reason,
            c.ttft.map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0),
            c.total.as_secs_f64() * 1e3,
        );
    }
    let snap = coord.metrics.snapshot();
    println!(
        "  aggregate: {:.0} tok/s at mean step occupancy {:.1}",
        snap.tokens_per_sec, snap.mean_batch_size
    );
    coord.shutdown();

    // machine-parseable cache report (the CI smoke test greps this line
    // and the nonzero-hit-rate check below fails the run outright)
    if let Some(c) = &cache {
        let s = c.snapshot();
        println!(
            "[cache] cache_hit_rate={:.3} hits={} misses={} resident_bytes={} \
             resident_experts={} budget_bytes={} evictions={} materializations={}",
            s.hit_rate(),
            s.hits,
            s.misses,
            s.resident_bytes,
            s.resident_experts,
            s.budget_bytes,
            s.evictions,
            s.materializations,
        );
        anyhow::ensure!(
            s.resident_bytes <= s.budget_bytes,
            "resident bytes exceed the configured budget"
        );
        anyhow::ensure!(
            !s.enabled || s.hits > 0,
            "expert cache enabled but served zero hits"
        );
    }

    // ------------------------------------------------------------------
    // Energy per inference on each device's DRAM
    // ------------------------------------------------------------------
    println!("\n== energy per inference (top-2 of {n_experts} experts) ==");
    let std_e = standard_moe_energy(n_experts, 2, lshape);
    let bf_e = butterfly_moe_energy(n_experts, 2, lshape);
    println!(
        "  standard: {:.1} µJ (dram {:.1} + compute {:.1})",
        std_e.total_nj() / 1e3,
        std_e.dram_nj / 1e3,
        std_e.compute_nj / 1e3
    );
    println!(
        "  butterfly: {:.1} µJ (dram {:.1} + compute {:.1})  -> {:.1}% savings",
        bf_e.total_nj() / 1e3,
        bf_e.dram_nj / 1e3,
        bf_e.compute_nj / 1e3,
        100.0 * (1.0 - bf_e.total_nj() / std_e.total_nj())
    );

    // battery framing (the paper's F2): inferences per mAh-class budget
    let battery_j = 10.0; // 10 J ≈ a coin cell's useful budget
    println!(
        "  a {battery_j:.0} J budget: {:.0}k standard vs {:.0}k butterfly inferences",
        battery_j / (std_e.total_nj() * 1e-9) / 1e3,
        battery_j / (bf_e.total_nj() * 1e-9) / 1e3,
    );
    Ok(())
}
